//! The L3 serving layer: a multi-tenant LASSO solve coordinator.
//!
//! Downstream users of a screening library rarely solve one problem:
//! they sweep λ grids for cross-validation across several datasets at
//! once (§5.3 of the paper is exactly this workload). The coordinator
//! turns the solvers into a service:
//!
//! * a dispatcher routes requests over **logical workers** with
//!   **dataset affinity** — all requests touching a dataset land on
//!   the same worker so its warm-start cache (last solution per
//!   (dataset, method, loss × penalty signature), valid for the next
//!   smaller λ) and its packed PJRT buffers are reused;
//! * within a worker, queued requests for the same dataset are
//!   **batched, sorted by descending λ and handed to the solver as one
//!   [`Solver::path_warm`](crate::solver::Solver::path_warm) session**
//!   (the Figure-6 trick, applied automatically) — warm-start chaining
//!   lives behind the solver API, not in the worker;
//! * every response carries a **safety certificate**: the KKT
//!   violation of the returned β on the full problem, computed through
//!   the method's own [`Solver::kkt_violation`] (plain-LASSO,
//!   group-norm or fused-transform conditions), checked by the
//!   coordinator, not trusted from the solver's gap.
//!
//! Workers are NOT threads: each logical worker is a queue plus an
//! engine/warm-cache slot, and draining a queue is a task on the
//! shared persistent pool ([`crate::runtime::pool`]). The engines'
//! parallel scans and sharded epochs fan out on the *same* pool (the
//! caller-participation scheduling makes that nesting deadlock-free),
//! so the whole serving stack runs on one fixed set of threads instead
//! of one thread per worker plus fresh spawns per epoch. A panicking
//! solve marks only its slot dead — surfaced by `submit`/`drain` as
//! [`CoordinatorError::WorkerDead`] — and the pool threads survive.
//!
//! Construction goes through [`Coordinator::builder`]; method dispatch
//! is a `Box<dyn Solver>` factory over [`Method`] (every solve
//! method — saif, dynscreen, gapsafe, hybrid, blitz, homotopy, fused,
//! group — is
//! servable, and fused requests may carry their dataset's real feature
//! tree in [`SolveRequest::tree`]), and per-request [`SolveSpec`]s can
//! override the worker defaults.
//!
//! Implementation is std-sync + channels (no tokio in the vendored
//! registry — DESIGN.md §4); workers own their engines behind slot
//! mutexes.

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cm::{Engine, EpochShards, NativeEngine, PoolMode};
use crate::linalg::{Parallelism, Precision};
use crate::metrics::LatencyStats;
use crate::model::Problem;
use crate::runtime::{pool, PjrtEngine};
pub use crate::solver::{Method, SolveSpec};
use crate::util::Stopwatch;

/// Which engine workers use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    Pjrt,
}

/// A solve request. `spec` carries the per-request solve knobs; its
/// `parallelism`/`epoch_shards`/`pool` (when `Some`) override the
/// worker defaults configured at build time.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    pub id: u64,
    /// Key for affinity/warm-start (same dataset ⇒ same key).
    pub dataset_key: u64,
    pub problem: Arc<Problem>,
    pub lam: f64,
    pub method: Method,
    /// Per-dataset feature tree for [`Method::Fused`] (edge list;
    /// ignored by every other method). `None` serves the chain tree
    /// 0−1−⋯−(p−1). The solve AND the response's safety certificate
    /// both use this tree.
    pub tree: Option<Arc<Vec<(usize, usize)>>>,
    /// Explicit warm-start seed for this request (a sparse β from a
    /// nearby — ideally larger — λ). `Some` overrides the worker's own
    /// warm cache for the session this request starts; the serving
    /// layer's λ-grid cache uses this to warm near-miss re-solves from
    /// the nearest cached solution. `None` (every pre-serving caller)
    /// keeps the worker-cache behavior exactly.
    pub warm: Option<Arc<Vec<(usize, f64)>>>,
    pub spec: SolveSpec,
}

/// A solve response with its safety certificate.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    pub id: u64,
    pub dataset_key: u64,
    pub lam: f64,
    pub beta: Vec<(usize, f64)>,
    pub gap: f64,
    /// KKT violation of β on the FULL problem, via the method's own
    /// optimality conditions (coordinator-verified).
    pub kkt_violation: f64,
    pub secs: f64,
    pub worker: usize,
    pub warm_started: bool,
}

/// Why a coordinator call failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CoordinatorError {
    /// A worker's solve task panicked (e.g. on an invalid request);
    /// its queued requests are lost and its slot accepts no more work.
    /// The pool threads themselves survive.
    WorkerDead { worker: usize },
    /// `submit_registered` named a dataset key that was never
    /// registered via [`Coordinator::register_saifbin`].
    UnknownDataset { key: u64 },
    /// `submit_registered` was asked to serve [`Method::Fused`]
    /// against an out-of-core dataset: the fused tree transform
    /// densifies the design (the full n×p in RAM, once per worker
    /// slot), which defeats registering by path. Submit fused
    /// problems inline via [`Coordinator::submit`] with an in-memory
    /// design (and a real [`SolveRequest::tree`]).
    FusedOnOutOfCore { key: u64 },
    /// [`Coordinator::register_saifbin`] could not open/decode the
    /// dataset file (IO error, bad magic, truncated header, …). The
    /// coordinator is unchanged: nothing was registered under `key`.
    RegisterFailed { key: u64, msg: String },
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordinatorError::WorkerDead { worker } => {
                write!(f, "coordinator worker {worker} died")
            }
            CoordinatorError::UnknownDataset { key } => {
                write!(f, "dataset key {key} is not registered")
            }
            CoordinatorError::FusedOnOutOfCore { key } => {
                write!(
                    f,
                    "fused requests against registered (out-of-core) dataset {key} would \
                     densify the design per worker slot; submit them inline with an \
                     in-memory design"
                )
            }
            CoordinatorError::RegisterFailed { key, msg } => {
                write!(f, "registering dataset {key} failed: {msg}")
            }
        }
    }
}

impl std::error::Error for CoordinatorError {}

/// Builder for [`Coordinator`] — the one construction path.
#[derive(Debug, Clone)]
pub struct CoordinatorBuilder {
    n_workers: usize,
    engine: EngineKind,
    parallelism: Parallelism,
    epoch_shards: EpochShards,
    pool: PoolMode,
    precision: Precision,
}

impl Default for CoordinatorBuilder {
    fn default() -> Self {
        CoordinatorBuilder {
            n_workers: 4,
            engine: EngineKind::Native,
            parallelism: Parallelism::Serial,
            epoch_shards: EpochShards::FollowParallelism,
            pool: PoolMode::default(),
            precision: Precision::default(),
        }
    }
}

impl CoordinatorBuilder {
    /// Logical worker count (default 4). The shared pool is grown to at
    /// least this many threads so every worker's queue can drain
    /// concurrently.
    pub fn workers(mut self, n: usize) -> Self {
        assert!(n >= 1, "coordinator needs at least one worker");
        self.n_workers = n;
        self
    }

    /// Engine kind workers solve with (default native f64).
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Default column parallelism for each worker's full-p scans
    /// (default serial: the coordinator already parallelizes across
    /// requests, so per-scan threading is opt-in for low-concurrency,
    /// huge-p workloads). Per-request `SolveSpec` overrides win.
    pub fn parallelism(mut self, par: Parallelism) -> Self {
        self.parallelism = par;
        self
    }

    /// Default sharding policy for the active-block CM epochs
    /// (default: follow the scan parallelism). Per-request `SolveSpec`
    /// overrides win.
    pub fn epoch_shards(mut self, shards: EpochShards) -> Self {
        self.epoch_shards = shards;
        self
    }

    /// Default threading substrate for the engines' scans and sharded
    /// epochs (default: the persistent pool). Per-request `SolveSpec`
    /// overrides win. Worker queue-drain tasks always run on the
    /// shared pool regardless — this only selects how solves fan out
    /// *within* a worker.
    pub fn pool(mut self, mode: PoolMode) -> Self {
        self.pool = mode;
        self
    }

    /// Default numeric policy for the workers' screening scans
    /// (default f64; see [`crate::linalg::mixed`] for what `MixedF32`
    /// changes — and what it provably does not). Per-request
    /// `SolveSpec` overrides win.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// A fresh, cold worker slot with this builder's engine defaults —
    /// used for every slot at [`CoordinatorBuilder::build`] time and
    /// again by [`Coordinator::recover_worker`] when a dead slot is
    /// respawned in place.
    fn new_slot(&self) -> Arc<WorkerSlot> {
        let mut native = NativeEngine::with_parallelism(self.parallelism);
        native.set_epoch_shards(self.epoch_shards);
        native.set_pool_mode(self.pool);
        let pjrt = match self.engine {
            EngineKind::Pjrt => PjrtEngine::new().ok(),
            EngineKind::Native => None,
        };
        Arc::new(WorkerSlot {
            queue: Mutex::new(VecDeque::new()),
            scheduled: AtomicBool::new(false),
            dead: AtomicBool::new(false),
            state: Mutex::new(WorkerState {
                native,
                pjrt,
                warm: BTreeMap::new(),
                defaults: (self.parallelism, self.epoch_shards, self.pool, self.precision),
            }),
        })
    }

    /// Set up the worker slots and return the running coordinator.
    pub fn build(self) -> Coordinator {
        // one pool thread per logical worker, so queue-drain tasks
        // never serialize behind each other
        pool::shared().ensure_threads(self.n_workers);
        let (res_tx, res_rx) = channel::<SolveResponse>();
        let slots: Vec<Arc<WorkerSlot>> = (0..self.n_workers).map(|_| self.new_slot()).collect();
        let n_workers = self.n_workers;
        Coordinator {
            slots,
            res_tx,
            results: res_rx,
            affinity: BTreeMap::new(),
            next_worker: 0,
            inflight: vec![0; n_workers],
            registered: BTreeMap::new(),
            config: self,
        }
    }

    /// Convenience: build, submit the whole batch, drain, shut down.
    pub fn run_batch(self, requests: Vec<SolveRequest>) -> Result<BatchRun, CoordinatorError> {
        let sw = Stopwatch::start();
        let mut c = self.build();
        for r in requests {
            c.submit(r)?;
        }
        let responses = c.drain()?;
        c.shutdown();
        Ok(BatchRun::collect(responses, sw.secs()))
    }
}

/// Outcome of [`CoordinatorBuilder::run_batch`].
#[derive(Debug)]
pub struct BatchRun {
    pub responses: Vec<SolveResponse>,
    pub latency: LatencyStats,
    pub wall_secs: f64,
}

impl BatchRun {
    /// Assemble a batch outcome from drained responses + wall time
    /// (folds the per-response latency) — the one place this summary
    /// is computed, shared by [`CoordinatorBuilder::run_batch`] and
    /// callers that drive `submit`/`drain` themselves (e.g. serving
    /// path-registered datasets).
    pub fn collect(responses: Vec<SolveResponse>, wall_secs: f64) -> BatchRun {
        let mut latency = LatencyStats::new();
        for r in &responses {
            latency.record_secs(r.secs);
        }
        BatchRun { responses, latency, wall_secs }
    }
}

/// One logical worker: its request queue, scheduling/liveness flags,
/// and the solver state (engines + warm cache) that persists across
/// pool tasks.
struct WorkerSlot {
    queue: Mutex<VecDeque<SolveRequest>>,
    /// Whether a pool task is currently (or about to be) draining the
    /// queue. At most one task runs per slot, so the engine state is
    /// effectively single-threaded even though it lives on a pool.
    scheduled: AtomicBool,
    /// Set when a solve panicked; the slot accepts no further work.
    dead: AtomicBool,
    state: Mutex<WorkerState>,
}

struct WorkerState {
    native: NativeEngine,
    pjrt: Option<PjrtEngine>,
    /// Warm-start cache: (dataset_key, method, problem signature) →
    /// (λ of last solution, solution). Keyed per method so a
    /// structured-penalty solution (fused is piecewise-constant, not
    /// sparse) can never seed a plain-LASSO session on the same
    /// dataset, and per loss × penalty signature ([`problem_sig`]) so
    /// the same dataset served under a different loss or elastic-net
    /// weight — a different optimization problem — never cross-seeds.
    warm: BTreeMap<(u64, Method, u64), (f64, Vec<(usize, f64)>)>,
    /// Build-time (parallelism, epoch_shards, pool, precision)
    /// defaults that per-request `SolveSpec` overrides fall back to.
    defaults: (Parallelism, EpochShards, PoolMode, Precision),
}

/// Forgiving lock: a poisoned mutex only ever belongs to a slot whose
/// `dead` flag keeps it from being reused for solves.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Warm-cache discriminator: which loss × penalty surface a solution
/// belongs to. The penalty half mirrors the [`crate::solver::Penalized`]
/// adapter's precedence (a non-plain problem-level penalty wins over
/// the spec's), so the signature matches what was actually solved.
fn problem_sig(prob: &Problem, spec: &SolveSpec) -> u64 {
    let pen = if !prob.penalty.is_plain() { prob.penalty } else { spec.penalty };
    prob.loss.fingerprint() ^ pen.fingerprint().rotate_left(17)
}

/// Seed equality for batching: two requests chain into one path session
/// only when they carry the SAME seed allocation (or both none) —
/// value comparison would let distinct-but-equal seeds merge, which is
/// fine for the math but makes session grouping depend on β contents.
fn same_warm(a: &Option<Arc<Vec<(usize, f64)>>>, b: &Option<Arc<Vec<(usize, f64)>>>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(x), Some(y)) => Arc::ptr_eq(x, y),
        _ => false,
    }
}

/// The coordinator.
pub struct Coordinator {
    slots: Vec<Arc<WorkerSlot>>,
    res_tx: Sender<SolveResponse>,
    results: Receiver<SolveResponse>,
    /// dataset_key → worker (sticky affinity)
    affinity: BTreeMap<u64, usize>,
    next_worker: usize,
    /// Outstanding requests per worker.
    inflight: Vec<usize>,
    /// Path-registered datasets: key → one [`Problem`] per worker
    /// slot, each holding its own read-only file handle + column cache
    /// ([`Coordinator::register_saifbin`]). Workers never contend on
    /// one handle's cache.
    registered: BTreeMap<u64, Vec<Arc<Problem>>>,
    /// The builder this coordinator was built from — kept so
    /// [`Coordinator::recover_worker`] can respawn a dead slot with the
    /// same engine defaults.
    config: CoordinatorBuilder,
}

impl Coordinator {
    /// Start configuring a coordinator.
    pub fn builder() -> CoordinatorBuilder {
        CoordinatorBuilder::default()
    }

    /// Sticky dataset-affine routing: the first request for a key
    /// picks the next worker round-robin; every later request for the
    /// same key lands on the same worker.
    fn route(&mut self, dataset_key: u64) -> usize {
        let n = self.slots.len();
        *self.affinity.entry(dataset_key).or_insert_with(|| {
            let w = self.next_worker;
            self.next_worker = (self.next_worker + 1) % n;
            w
        })
    }

    /// Queue a routed request on its worker and schedule a pool task
    /// to drain the queue if none is running.
    fn enqueue(&mut self, worker: usize, req: SolveRequest) -> Result<(), CoordinatorError> {
        let slot = &self.slots[worker];
        if slot.dead.load(Ordering::Acquire) {
            return Err(CoordinatorError::WorkerDead { worker });
        }
        lock(&slot.queue).push_back(req);
        self.inflight[worker] += 1;
        if !slot.scheduled.swap(true, Ordering::AcqRel) {
            let slot = slot.clone();
            let res_tx = self.res_tx.clone();
            pool::shared().spawn(move || worker_task(worker, slot, res_tx));
        }
        Ok(())
    }

    /// Submit a request (dataset-affine routing) and schedule a pool
    /// task to drain the worker's queue if none is running. Fails with
    /// the dead worker's id if the affine worker's slot has died.
    pub fn submit(&mut self, req: SolveRequest) -> Result<(), CoordinatorError> {
        let worker = self.route(req.dataset_key);
        self.enqueue(worker, req)
    }

    /// Register a `.saifbin` dataset under `key` for out-of-core
    /// serving: the file is opened once per worker slot, so each
    /// worker streams through its OWN read-only handle and hot-column
    /// cache (no cross-worker cache contention, no shared cursor). The
    /// column norms are computed once — one streaming pass — and
    /// shared across the slots' problems. Returns the registered
    /// problem (slot 0's handle) so callers can read n/p/λ_max without
    /// opening the file again. Failures surface as the typed
    /// [`CoordinatorError::RegisterFailed`] — the same error enum
    /// `submit`/`drain` use — and leave the coordinator unchanged.
    pub fn register_saifbin(
        &mut self,
        key: u64,
        path: &str,
    ) -> Result<Arc<Problem>, CoordinatorError> {
        let fail = |msg: String| CoordinatorError::RegisterFailed { key, msg };
        let ds = crate::data::io::read_saifbin(path).map_err(&fail)?;
        let prob0 = Arc::new(ds.problem());
        let mat = match &prob0.x {
            crate::linalg::Design::OocCsc(m) => m.clone(),
            _ => unreachable!("read_saifbin always yields an out-of-core design"),
        };
        let mut probs = Vec::with_capacity(self.slots.len());
        probs.push(prob0.clone());
        for _ in 1..self.slots.len() {
            let mut p = (*prob0).clone();
            p.x = crate::linalg::Design::OocCsc(
                mat.reopen().map_err(|e| fail(format!("reopen {path}: {e}")))?,
            );
            probs.push(Arc::new(p));
        }
        self.registered.insert(key, probs);
        Ok(prob0)
    }

    /// The affine worker slot's own problem handle for a dataset
    /// registered via [`Coordinator::register_saifbin`], routing the
    /// key (which claims its round-robin slot on first use). Callers
    /// that build [`SolveRequest`]s themselves — the serving layer,
    /// which needs per-request warm seeds `submit_registered` does not
    /// carry — submit against this handle so every request for the key
    /// shares one `Arc` and keeps the per-slot out-of-core isolation.
    pub fn registered_problem(&mut self, key: u64) -> Option<Arc<Problem>> {
        if !self.registered.contains_key(&key) {
            return None;
        }
        let worker = self.route(key);
        Some(self.registered[&key][worker].clone())
    }

    /// Submit a solve against a dataset registered by path
    /// ([`Coordinator::register_saifbin`]): the request is routed by
    /// affinity, then built around the affine worker slot's own
    /// problem handle. All requests for one key share that slot's
    /// `Arc`, so the worker batches them into λ-path sessions exactly
    /// like inline submissions.
    ///
    /// [`Method::Fused`] is rejected here
    /// ([`CoordinatorError::FusedOnOutOfCore`]): its tree transform
    /// densifies the design, which an out-of-core registration exists
    /// to avoid — submit fused problems inline via
    /// [`Coordinator::submit`] with an in-memory design and
    /// [`SolveRequest::tree`] set.
    pub fn submit_registered(
        &mut self,
        id: u64,
        key: u64,
        lam: f64,
        method: Method,
        spec: SolveSpec,
    ) -> Result<(), CoordinatorError> {
        // validate BEFORE routing: a failed probe must not burn a
        // round-robin slot or leave a phantom affinity entry
        if matches!(method, Method::Fused) {
            return Err(CoordinatorError::FusedOnOutOfCore { key });
        }
        if !self.registered.contains_key(&key) {
            return Err(CoordinatorError::UnknownDataset { key });
        }
        let worker = self.route(key);
        let problem = self.registered[&key][worker].clone();
        self.enqueue(
            worker,
            SolveRequest {
                id,
                dataset_key: key,
                problem,
                lam,
                method,
                tree: None,
                warm: None,
                spec,
            },
        )
    }

    /// Wait for all in-flight responses. Fails with the dead worker's
    /// id if a worker dies while it still owes responses (its queued
    /// work is lost; responses already received are dropped with it —
    /// resubmit on a fresh coordinator).
    pub fn drain(&mut self) -> Result<Vec<SolveResponse>, CoordinatorError> {
        let total: usize = self.inflight.iter().sum();
        let mut out = Vec::with_capacity(total);
        while self.inflight.iter().sum::<usize>() > 0 {
            match self.recv_one(Duration::from_millis(25)) {
                Ok(Some(r)) => out.push(r),
                Ok(None) => {}
                Err(CoordinatorError::WorkerDead { worker }) => {
                    // drain's contract: the dead worker's owed work is
                    // written off (recover_worker offers the
                    // keep-serving alternative)
                    self.inflight[worker] = 0;
                    return Err(CoordinatorError::WorkerDead { worker });
                }
                Err(e) => return Err(e),
            }
        }
        Ok(out)
    }

    /// Receive ONE completed response, waiting up to `timeout` — the
    /// per-response pump the serving layer drives instead of the
    /// all-or-nothing [`Coordinator::drain`]. `Ok(None)` means the wait
    /// timed out with every worker healthy; a dead worker that still
    /// owes responses surfaces as [`CoordinatorError::WorkerDead`]
    /// *without* writing off its in-flight count, so the caller can
    /// [`Coordinator::recover_worker`] and resubmit.
    pub fn recv_one(&mut self, timeout: Duration) -> Result<Option<SolveResponse>, CoordinatorError> {
        match self.results.recv_timeout(timeout) {
            Ok(r) => {
                // saturating: a recovered slot had its count reset, but
                // responses its predecessor sent before dying may still
                // arrive afterwards
                self.inflight[r.worker] = self.inflight[r.worker].saturating_sub(1);
                Ok(Some(r))
            }
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                // a worker still owing responses whose task died can
                // never answer: surface it
                let dead = (0..self.inflight.len()).find(|&w| {
                    self.inflight[w] > 0 && self.slots[w].dead.load(Ordering::Acquire)
                });
                match dead {
                    Some(worker) => Err(CoordinatorError::WorkerDead { worker }),
                    None => Ok(None),
                }
            }
        }
    }

    /// The worker a dataset's requests are (or would be) routed to, if
    /// an affinity exists. Read-only: unlike `route` this never claims
    /// a round-robin slot.
    pub fn worker_of(&self, dataset_key: u64) -> Option<usize> {
        self.affinity.get(&dataset_key).copied()
    }

    /// Workers whose slot has died (a solve panicked) since the last
    /// recovery. Candidates for [`Coordinator::recover_worker`].
    pub fn dead_workers(&self) -> Vec<usize> {
        (0..self.slots.len())
            .filter(|&w| self.slots[w].dead.load(Ordering::Acquire))
            .collect()
    }

    /// Respawn a dead (or live — it is simply replaced) worker slot in
    /// place: fresh engines, cold warm cache, empty queue, same index —
    /// so dataset affinities and registered per-slot problem handles
    /// stay valid. Returns the requests that were still queued on the
    /// old slot (accepted but never started); requests from the batch
    /// that panicked are NOT among them — callers that must not drop
    /// accepted work (the serving layer) track their own pending set
    /// and resubmit from it. The in-flight count for the slot is reset.
    pub fn recover_worker(&mut self, worker: usize) -> Vec<SolveRequest> {
        let orphaned: Vec<SolveRequest> = lock(&self.slots[worker].queue).drain(..).collect();
        self.slots[worker] = self.config.new_slot();
        self.inflight[worker] = 0;
        orphaned
    }

    /// Replace the response channel: every response from here on is
    /// delivered to `tx` instead of the internal channel
    /// [`Coordinator::drain`]/[`Coordinator::recv_one`] read. The
    /// serving layer uses this to pump responses without holding its
    /// coordinator lock across a blocking receive; after redirection,
    /// `drain`/`recv_one` only ever time out — the caller owns delivery
    /// AND the in-flight accounting that comes with it.
    pub fn redirect_responses(&mut self, tx: Sender<SolveResponse>) {
        self.res_tx = tx;
    }

    /// Wait for every live worker to go idle. There are no threads to
    /// join — the pool outlives the coordinator — so this only ensures
    /// no task still borrows the slots when they drop.
    pub fn shutdown(self) {
        for slot in &self.slots {
            while !slot.dead.load(Ordering::Acquire)
                && (slot.scheduled.load(Ordering::Acquire) || !lock(&slot.queue).is_empty())
            {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// Pool task: drain one worker's queue, batch by batch, until it is
/// empty. Exactly one task runs per slot (`scheduled` gates spawns);
/// a panicking batch marks the slot dead and leaves `scheduled` set so
/// nothing reuses the poisoned state.
fn worker_task(wid: usize, slot: Arc<WorkerSlot>, res_tx: Sender<SolveResponse>) {
    loop {
        let batch: Vec<SolveRequest> = lock(&slot.queue).drain(..).collect();
        if batch.is_empty() {
            slot.scheduled.store(false, Ordering::Release);
            // close the submit race: a request enqueued between the
            // drain and the store above must not strand
            if lock(&slot.queue).is_empty() || slot.scheduled.swap(true, Ordering::AcqRel) {
                return;
            }
            continue;
        }
        let r = catch_unwind(AssertUnwindSafe(|| {
            let mut state = lock(&slot.state);
            process_batch(wid, &mut state, batch, &res_tx);
        }));
        if r.is_err() {
            slot.dead.store(true, Ordering::Release);
            return;
        }
    }
}

/// Batch its queue, group it into per-dataset λ-descending path
/// sessions, and run each through the unified solver API.
fn process_batch(
    wid: usize,
    state: &mut WorkerState,
    mut batch: Vec<SolveRequest>,
    res_tx: &Sender<SolveResponse>,
) {
    let (par, shards, pool_mode, precision) = state.defaults;
    // dataset-major, λ-descending order ⇒ warm starts chain down paths
    batch.sort_by(|a, b| {
        a.dataset_key
            .cmp(&b.dataset_key)
            .then(b.lam.total_cmp(&a.lam))
    });
    // each maximal run with the same (dataset, problem, method, tree,
    // warm seed, spec) is one λ-path session behind `Solver::path_warm`
    let mut i = 0;
    while i < batch.len() {
        let mut j = i + 1;
        while j < batch.len()
            && batch[j].dataset_key == batch[i].dataset_key
            && Arc::ptr_eq(&batch[j].problem, &batch[i].problem)
            && batch[j].method == batch[i].method
            && batch[j].tree == batch[i].tree
            && same_warm(&batch[j].warm, &batch[i].warm)
            && batch[j].spec == batch[i].spec
        {
            j += 1;
        }
        let chunk = &batch[i..j];
        i = j;

        let first = &chunk[0];
        let prob = &*first.problem;
        // precision is a solver knob, not an engine knob: fold the
        // worker default into the spec the solver factory sees
        let mut spec = first.spec.clone();
        if spec.precision.is_none() {
            spec.precision = Some(precision);
        }
        let spec = &spec;
        let use_pjrt = match &state.pjrt {
            Some(e) => e.supports(prob, 1) && prob.offset.is_none(),
            None => false,
        };
        let engine: &mut dyn Engine = match (use_pjrt, state.pjrt.as_mut()) {
            (true, Some(e)) => e as &mut dyn Engine,
            _ => &mut state.native as &mut dyn Engine,
        };
        // per-request overrides over the worker defaults
        engine.set_parallelism(spec.parallelism.unwrap_or(par));
        engine.set_epoch_shards(spec.epoch_shards.unwrap_or(shards));
        engine.set_pool_mode(spec.pool.unwrap_or(pool_mode));

        let lams: Vec<f64> = chunk.iter().map(|r| r.lam).collect();
        // an explicit per-request seed (the serving cache's nearest
        // cached β) wins over the worker's own warm cache
        let sig = problem_sig(prob, spec);
        let seed = match &first.warm {
            Some(w) => Some(w.to_vec()),
            None => state
                .warm
                .get(&(first.dataset_key, first.method, sig))
                .filter(|(l, _)| *l >= first.lam)
                .map(|(_, b)| b.clone()),
        };
        let tree = first.tree.as_ref().map(|t| &t[..]);
        let mut solver = crate::solver::make_with_tree(first.method, engine, spec, tree);
        let path = solver.path_warm(prob, &lams, seed.as_deref());
        for (req, sol) in chunk.iter().zip(&path.points) {
            // coordinator-side safety certificate, through the
            // method's own optimality conditions
            let kkt_violation = solver.kkt_violation(prob, &sol.beta, req.lam);
            let _ = res_tx.send(SolveResponse {
                id: req.id,
                dataset_key: req.dataset_key,
                lam: req.lam,
                beta: sol.beta.clone(),
                gap: sol.gap,
                kkt_violation,
                secs: sol.secs,
                worker: wid,
                warm_started: sol.warm_started,
            });
        }
        drop(solver);
        if let (Some(req), Some(sol)) = (chunk.last(), path.points.last()) {
            state
                .warm
                .insert((req.dataset_key, req.method, sig), (req.lam, sol.beta.clone()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn requests_for(
        prob: Arc<Problem>,
        key: u64,
        fracs: &[f64],
        base_id: u64,
    ) -> Vec<SolveRequest> {
        let lam_max = prob.lambda_max();
        fracs
            .iter()
            .enumerate()
            .map(|(i, f)| SolveRequest {
                id: base_id + i as u64,
                dataset_key: key,
                problem: prob.clone(),
                lam: lam_max * f,
                method: Method::Saif,
                tree: None,
                warm: None,
                spec: SolveSpec { eps: 1e-8, ..Default::default() },
            })
            .collect()
    }

    fn run(
        reqs: Vec<SolveRequest>,
        builder: CoordinatorBuilder,
    ) -> (Vec<SolveResponse>, LatencyStats, f64) {
        let b = builder.run_batch(reqs).expect("workers alive");
        (b.responses, b.latency, b.wall_secs)
    }

    #[test]
    fn batch_solves_all_and_certifies() {
        let p1 = Arc::new(synth::synth_linear(40, 200, 201).problem());
        let p2 = Arc::new(synth::synth_linear(40, 150, 202).problem());
        let mut reqs = requests_for(p1.clone(), 1, &[0.5, 0.2, 0.1], 0);
        reqs.extend(requests_for(p2.clone(), 2, &[0.4, 0.15], 100));
        let (responses, lat, _wall) = run(reqs, Coordinator::builder().workers(2));
        assert_eq!(responses.len(), 5);
        assert_eq!(lat.count(), 5);
        for r in &responses {
            assert!(r.gap <= 1e-8);
            let lam = r.lam;
            assert!(
                r.kkt_violation < 1e-3 * lam.max(1.0),
                "req {} kkt {}",
                r.id,
                r.kkt_violation
            );
        }
    }

    #[test]
    fn sparse_dataset_solves_end_to_end_with_certificate() {
        // a CSC design flows through the coordinator untouched and the
        // KKT certificate is checked on the sparse problem
        let ds = synth::synth_sparse(60, 800, 0.05, 301);
        assert!(ds.x.is_sparse());
        let prob = Arc::new(ds.problem());
        let mut reqs = requests_for(prob.clone(), 7, &[0.3, 0.1], 0);
        for (i, r) in reqs.iter_mut().enumerate() {
            r.method = if i == 0 { Method::Saif } else { Method::DynScreen };
        }
        let (responses, _, _) = run(
            reqs,
            Coordinator::builder().workers(2).parallelism(Parallelism::Fixed(2)),
        );
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert!(r.gap <= 1e-8, "gap {}", r.gap);
            assert!(
                r.kkt_violation < 1e-3 * r.lam.max(1.0),
                "sparse req {}: kkt {}",
                r.id,
                r.kkt_violation
            );
        }
    }

    #[test]
    fn sharded_epoch_policy_solves_and_certifies() {
        let prob = Arc::new(synth::synth_linear(40, 400, 206).problem());
        let reqs = requests_for(prob.clone(), 3, &[0.3, 0.1, 0.05], 0);
        let (responses, _, _) = run(
            reqs,
            Coordinator::builder()
                .workers(2)
                .parallelism(Parallelism::Fixed(2))
                .epoch_shards(EpochShards::Fixed(3)),
        );
        assert_eq!(responses.len(), 3);
        for r in &responses {
            assert!(r.gap <= 1e-8, "gap {}", r.gap);
            assert!(
                r.kkt_violation < 1e-3 * r.lam.max(1.0),
                "sharded-epoch req {}: kkt {}",
                r.id,
                r.kkt_violation
            );
        }
    }

    #[test]
    fn scoped_pool_mode_matches_persistent_bitwise() {
        // the builder's pool substrate must not change a bit of any
        // response: same requests, both modes, identical solutions
        let prob = Arc::new(synth::synth_linear(40, 400, 210).problem());
        let solve = |mode: PoolMode| {
            let reqs = requests_for(prob.clone(), 1, &[0.3, 0.15, 0.08], 0);
            let (mut responses, _, _) = run(
                reqs,
                Coordinator::builder()
                    .workers(1)
                    .parallelism(Parallelism::Fixed(2))
                    .epoch_shards(EpochShards::Fixed(2))
                    .pool(mode),
            );
            responses.sort_by_key(|r| r.id);
            responses
        };
        let (a, b) = (solve(PoolMode::Persistent), solve(PoolMode::Scoped));
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.beta, rb.beta, "req {}: pooled β ≠ scoped β", ra.id);
            assert_eq!(ra.gap.to_bits(), rb.gap.to_bits());
            assert_eq!(ra.kkt_violation.to_bits(), rb.kkt_violation.to_bits());
        }
    }

    #[test]
    fn per_request_spec_overrides_worker_defaults() {
        // a request pinning its own epoch-shard policy, pool substrate
        // and ε solves and certifies on a serial-default coordinator
        let prob = Arc::new(synth::synth_linear(40, 300, 208).problem());
        let lam_max = prob.lambda_max();
        let reqs = vec![
            SolveRequest {
                id: 0,
                dataset_key: 1,
                problem: prob.clone(),
                lam: lam_max * 0.2,
                method: Method::Saif,
                tree: None,
                warm: None,
                spec: SolveSpec {
                    eps: 1e-9,
                    parallelism: Some(Parallelism::Fixed(2)),
                    epoch_shards: Some(EpochShards::Fixed(2)),
                    pool: Some(PoolMode::Scoped),
                    ..Default::default()
                },
            },
            SolveRequest {
                id: 1,
                dataset_key: 1,
                problem: prob.clone(),
                lam: lam_max * 0.1,
                method: Method::Saif,
                tree: None,
                warm: None,
                spec: SolveSpec { eps: 1e-8, ..Default::default() },
            },
        ];
        let (responses, _, _) = run(reqs, Coordinator::builder().workers(1));
        assert_eq!(responses.len(), 2);
        for r in &responses {
            let eps = if r.id == 0 { 1e-9 } else { 1e-8 };
            assert!(r.gap <= eps, "req {}: gap {}", r.id, r.gap);
            assert!(r.kkt_violation < 1e-3 * r.lam.max(1.0));
        }
    }

    #[test]
    fn elastic_net_requests_serve_and_certify() {
        use crate::model::Penalty;
        let prob = Arc::new(synth::synth_linear(30, 120, 213).problem());
        let pen = Penalty::ridge(0.3);
        let mut reqs = requests_for(prob.clone(), 1, &[0.3, 0.15], 0);
        for r in &mut reqs {
            r.spec.penalty = pen;
        }
        let (responses, _, _) = run(reqs, Coordinator::builder().workers(1));
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert!(r.gap <= 1e-8, "gap {}", r.gap);
            // the response certificate IS the elastic-net KKT system
            assert!(
                r.kkt_violation < 1e-3 * r.lam.max(1.0),
                "enet kkt {}",
                r.kkt_violation
            );
            let viol = prob.kkt_violation_with(&r.beta, r.lam, pen);
            assert!((viol - r.kkt_violation).abs() < 1e-12);
        }
    }

    #[test]
    fn dataset_affinity_holds() {
        let p1 = Arc::new(synth::synth_linear(30, 100, 203).problem());
        let p2 = Arc::new(synth::synth_linear(30, 100, 204).problem());
        let mut reqs = requests_for(p1.clone(), 10, &[0.5, 0.3, 0.2, 0.1], 0);
        reqs.extend(requests_for(p2.clone(), 20, &[0.5, 0.3, 0.2, 0.1], 100));
        let (responses, _, _) = run(reqs, Coordinator::builder().workers(3));
        let mut per_ds: std::collections::HashMap<u64, std::collections::HashSet<usize>> =
            Default::default();
        for r in &responses {
            per_ds.entry(r.dataset_key).or_default().insert(r.worker);
        }
        for (ds, workers) in per_ds {
            assert_eq!(workers.len(), 1, "dataset {ds} split across workers");
        }
    }

    #[test]
    fn warm_start_used_on_descending_lambda() {
        let p1 = Arc::new(synth::synth_linear(30, 150, 205).problem());
        let reqs = requests_for(p1, 1, &[0.5, 0.25, 0.1], 0);
        let (responses, _, _) = run(reqs, Coordinator::builder().workers(1));
        // whether the λs landed in one batch (one path session) or
        // split across drain tasks (warm cache seeding), all but the
        // first must warm-start
        let warm_count = responses.iter().filter(|r| r.warm_started).count();
        assert!(warm_count >= 2, "warm {warm_count}");
    }

    #[test]
    fn mixed_methods_agree_on_support() {
        let prob = Arc::new(synth::synth_linear(40, 150, 207).problem());
        let lam = prob.lambda_max() * 0.15;
        let reqs: Vec<SolveRequest> = [Method::Saif, Method::DynScreen, Method::Blitz]
            .iter()
            .enumerate()
            .map(|(i, &m)| SolveRequest {
                id: i as u64,
                dataset_key: i as u64, // different keys: no warm reuse
                problem: prob.clone(),
                lam,
                method: m,
                tree: None,
                warm: None,
                spec: SolveSpec { eps: 1e-9, ..Default::default() },
            })
            .collect();
        let (responses, _, _) = run(reqs, Coordinator::builder().workers(3));
        let mut supports: Vec<Vec<usize>> = responses
            .iter()
            .map(|r| {
                let mut s: Vec<usize> =
                    r.beta.iter().filter(|(_, b)| b.abs() > 1e-7).map(|&(i, _)| i).collect();
                s.sort();
                s
            })
            .collect();
        supports.dedup();
        assert_eq!(supports.len(), 1, "methods disagree: {supports:?}");
    }

    #[test]
    fn registered_saifbin_dataset_serves_with_certificates() {
        let ds = synth::synth_sparse(40, 300, 0.05, 401);
        let path =
            std::env::temp_dir().join(format!("saif_coord_reg_{}.saifbin", std::process::id()));
        let path = path.to_str().unwrap();
        crate::data::io::write_saifbin(&ds, path).unwrap();
        let prob_mem = ds.problem();
        let lam_max = prob_mem.lambda_max();

        let mut c = Coordinator::builder().workers(2).build();
        // unknown key fails cleanly before anything is queued
        assert_eq!(
            c.submit_registered(0, 9, lam_max, Method::Saif, SolveSpec::default()),
            Err(CoordinatorError::UnknownDataset { key: 9 })
        );
        c.register_saifbin(9, path).unwrap();
        for (i, f) in [0.3f64, 0.1].iter().enumerate() {
            c.submit_registered(
                i as u64,
                9,
                lam_max * f,
                Method::Saif,
                SolveSpec { eps: 1e-8, ..Default::default() },
            )
            .unwrap();
        }
        let responses = c.drain().unwrap();
        c.shutdown();
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert!(r.gap <= 1e-8, "gap {}", r.gap);
            // certify against the IN-MEMORY problem: the out-of-core
            // solve must be optimal for the same data
            let viol = prob_mem.kkt_violation(&r.beta, r.lam);
            assert!(viol < 1e-3 * r.lam.max(1.0), "req {}: kkt {viol}", r.id);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn register_failure_is_a_typed_error() {
        let mut c = Coordinator::builder().workers(2).build();
        let err = c.register_saifbin(4, "/nonexistent/dir/nope.saifbin").unwrap_err();
        match err {
            CoordinatorError::RegisterFailed { key, msg } => {
                assert_eq!(key, 4);
                assert!(!msg.is_empty());
            }
            other => panic!("expected RegisterFailed, got {other:?}"),
        }
        // nothing was registered: submits against the key still fail
        assert_eq!(
            c.submit_registered(0, 4, 0.5, Method::Saif, SolveSpec::default()),
            Err(CoordinatorError::UnknownDataset { key: 4 })
        );
        c.shutdown();
    }

    #[test]
    fn explicit_warm_seed_is_consumed() {
        // a request carrying its own seed must warm-start even on a
        // coordinator whose worker cache has never seen the dataset
        let prob = Arc::new(synth::synth_linear(30, 120, 211).problem());
        let lam_max = prob.lambda_max();
        let mut c = Coordinator::builder().workers(1).build();
        c.submit(SolveRequest {
            id: 0,
            dataset_key: 1,
            problem: prob.clone(),
            lam: lam_max * 0.2,
            method: Method::Saif,
            tree: None,
            warm: None,
            spec: SolveSpec { eps: 1e-8, ..Default::default() },
        })
        .unwrap();
        let cold = c.drain().unwrap().pop().unwrap();
        assert!(!cold.warm_started);
        c.submit(SolveRequest {
            id: 1,
            dataset_key: 2, // fresh key: the worker cache has no seed
            problem: prob.clone(),
            lam: lam_max * 0.18,
            method: Method::Saif,
            tree: None,
            warm: Some(Arc::new(cold.beta.clone())),
            spec: SolveSpec { eps: 1e-8, ..Default::default() },
        })
        .unwrap();
        let warmed = c.drain().unwrap().pop().unwrap();
        assert!(warmed.warm_started, "explicit seed must be consumed");
        assert!(warmed.gap <= 1e-8);
        assert!(warmed.kkt_violation < 1e-3 * warmed.lam.max(1.0));
        c.shutdown();
    }

    #[test]
    fn dead_worker_recovers_in_place() {
        // poison the only worker (group method asserts LS-only, the
        // logistic problem panics it), then recover the slot and serve
        // again on the SAME coordinator
        let bad = Arc::new(synth::gisette_like(30, 40, 38).problem());
        let good = Arc::new(synth::synth_linear(30, 100, 212).problem());
        let lam_bad = bad.lambda_max() * 0.5;
        let lam_good = good.lambda_max() * 0.2;
        let mut c = Coordinator::builder().workers(1).build();
        c.submit(SolveRequest {
            id: 0,
            dataset_key: 0,
            problem: bad,
            lam: lam_bad,
            method: Method::Group { size: 4 }, // LS-only: panics on logistic
            tree: None,
            warm: None,
            spec: SolveSpec::default(),
        })
        .unwrap();
        assert_eq!(c.drain(), Err(CoordinatorError::WorkerDead { worker: 0 }));
        assert_eq!(c.dead_workers(), vec![0]);
        let orphaned = c.recover_worker(0);
        assert!(orphaned.is_empty(), "nothing was left queued");
        assert!(c.dead_workers().is_empty());
        // the respawned slot serves; affinity still routes key 0 to it
        assert_eq!(c.worker_of(0), Some(0));
        c.submit(SolveRequest {
            id: 1,
            dataset_key: 0,
            problem: good,
            lam: lam_good,
            method: Method::Saif,
            tree: None,
            warm: None,
            spec: SolveSpec { eps: 1e-8, ..Default::default() },
        })
        .unwrap();
        let r = c.drain().unwrap().pop().unwrap();
        assert_eq!(r.id, 1);
        assert!(r.gap <= 1e-8);
        c.shutdown();
    }

    #[test]
    fn submit_after_drain_reuses_the_idle_worker() {
        // the schedule flag must re-arm once a queue drains: a second
        // wave of requests on the same coordinator must still be served
        let prob = Arc::new(synth::synth_linear(30, 100, 209).problem());
        let mut c = Coordinator::builder().workers(2).build();
        for r in requests_for(prob.clone(), 1, &[0.3, 0.1], 0) {
            c.submit(r).unwrap();
        }
        assert_eq!(c.drain().unwrap().len(), 2);
        for r in requests_for(prob, 1, &[0.05], 100) {
            c.submit(r).unwrap();
        }
        let second = c.drain().unwrap();
        assert_eq!(second.len(), 1);
        assert!(second[0].warm_started, "second wave must hit the warm cache");
        c.shutdown();
    }
}
