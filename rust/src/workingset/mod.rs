//! BLITZ-like working-set method (Johnson & Guestrin 2015) — the
//! paper's working-set baseline (§1.3, Figures 2 and 5).
//!
//! Sketch of the reimplementation (the original is a Matlab/C
//! package; DESIGN.md §4): maintain a globally feasible dual point
//! θ_feas and a working set W.
//!
//! 1. solve the sub-problem restricted to W (CM, to tolerance ξ·gap);
//! 2. the sub-problem dual θ_sub may violate constraints outside W:
//!    back-track α ∈ [0, 1] so θ' = (1−α)θ_feas + α·θ_sub is feasible
//!    for ALL constraints (the "extreme feasible point");
//! 3. global duality gap at (β, θ'): done if ≤ ε;
//! 4. otherwise rebuild W with the constraints *closest to θ'*
//!    (distance (1 − |x_iᵀθ'|)/‖x_i‖), doubling the budget, always
//!    keeping the support of β.

use crate::cm::{solve_subproblem, Engine};
use crate::model::Problem;
use crate::util::{tmax, Stopwatch};

/// BLITZ configuration.
#[derive(Debug, Clone)]
pub struct BlitzConfig {
    /// Final duality-gap tolerance ε.
    pub eps: f64,
    /// Sub-problem gap tolerance as a fraction of the current global
    /// gap (BLITZ solves sub-problems only as far as useful).
    pub xi: f64,
    /// Initial working-set budget.
    pub init_budget: usize,
    pub k_epochs: usize,
    pub max_outer: usize,
}

impl Default for BlitzConfig {
    fn default() -> Self {
        BlitzConfig { eps: 1e-6, xi: 0.1, init_budget: 32, k_epochs: 10, max_outer: 10_000 }
    }
}

impl BlitzConfig {
    /// Map the method-agnostic [`SolveSpec`](crate::solver::SolveSpec)
    /// onto BLITZ's config.
    pub fn from_spec(spec: &crate::solver::SolveSpec) -> BlitzConfig {
        let d = BlitzConfig::default();
        BlitzConfig {
            eps: spec.eps,
            max_outer: spec.max_outer.unwrap_or(d.max_outer),
            ..d
        }
    }
}

/// Result of a BLITZ solve.
#[derive(Debug, Clone)]
pub struct BlitzResult {
    pub beta: Vec<(usize, f64)>,
    pub gap: f64,
    pub outer_iters: usize,
    pub epochs: usize,
    pub max_working: usize,
    pub secs: f64,
}

/// BLITZ-like solver.
pub struct Blitz<'a> {
    pub cfg: BlitzConfig,
    pub engine: &'a mut dyn Engine,
}

impl<'a> Blitz<'a> {
    pub fn new(engine: &'a mut dyn Engine, cfg: BlitzConfig) -> Self {
        Blitz { cfg, engine }
    }

    pub fn solve(&mut self, prob: &Problem, lam: f64) -> BlitzResult {
        let sw = Stopwatch::start();
        let p = prob.p();
        let col_nrm: Vec<f64> = prob.col_nrm2.iter().map(|v| v.sqrt()).collect();

        // globally feasible start: θ at β = 0 rescaled over ALL columns
        let u0 = prob
            .offset
            .clone()
            .unwrap_or_else(|| vec![0.0; prob.n()]);
        let th_hat = prob.theta_hat(&u0, lam);
        let mut scores = self.engine.scores(prob, &th_hat);
        let mx0 = scores.iter().cloned().fold(0.0, tmax);
        let mut theta_feas = prob.project_dual(&th_hat, mx0, lam).theta;

        let mut budget = self.cfg.init_budget.min(p);
        let mut beta_full = vec![0.0; p];
        let mut outer = 0usize;
        let mut epochs = 0usize;
        let mut max_working = 0usize;
        let mut gap = f64::INFINITY;

        loop {
            outer += 1;
            // working set = support ∪ top-`budget` closest constraints
            for (i, s) in scores.iter_mut().enumerate() {
                // distance of constraint i's boundary to θ_feas
                *s = (1.0 - prob.x.col_dot(i, &theta_feas).abs()).max(0.0)
                    / col_nrm[i].max(1e-12);
            }
            let mut order: Vec<usize> = (0..p).collect();
            order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
            let mut work: Vec<usize> = Vec::with_capacity(budget + 8);
            let mut in_work = vec![false; p];
            for i in 0..p {
                if beta_full[i] != 0.0 {
                    in_work[i] = true;
                    work.push(i);
                }
            }
            for &i in order.iter() {
                if work.len() >= budget {
                    break;
                }
                if !in_work[i] {
                    in_work[i] = true;
                    work.push(i);
                }
            }
            max_working = max_working.max(work.len());

            // sub-problem solve to a fraction of the current gap
            let sub_eps = if gap.is_finite() {
                (self.cfg.xi * gap).max(self.cfg.eps * 0.5)
            } else {
                self.cfg.eps
            };
            let mut beta: Vec<f64> = work.iter().map(|&i| beta_full[i]).collect();
            let (eval, e) = solve_subproblem(
                self.engine,
                prob,
                &work,
                &mut beta,
                lam,
                sub_eps,
                self.cfg.k_epochs,
                200_000,
            );
            epochs += e;
            for (a, &i) in work.iter().enumerate() {
                beta_full[i] = beta[a];
            }

            // back-track θ_sub toward θ_feas until globally feasible
            let all = self.engine.scores(prob, &eval.theta);
            let mut alpha = 1.0f64;
            for i in 0..p {
                if all[i] > 1.0 {
                    // |a + α(b−a)| ≤ 1 with a = x_iᵀθ_feas, b = x_iᵀθ_sub
                    let a = prob.x.col_dot(i, &theta_feas);
                    let b = prob.x.col_dot(i, &eval.theta);
                    let hi = (1.0 - a) / (b - a);
                    let lo = (-1.0 - a) / (b - a);
                    let step = hi.max(lo);
                    if step.is_finite() && step >= 0.0 {
                        alpha = alpha.min(step);
                    }
                }
            }
            for j in 0..theta_feas.len() {
                theta_feas[j] += alpha * (eval.theta[j] - theta_feas[j]);
            }
            // global gap at (β, θ_feas)
            let sparse: Vec<(usize, f64)> = work
                .iter()
                .map(|&i| (i, beta_full[i]))
                .filter(|&(_, b)| b != 0.0)
                .collect();
            let uu = prob.margins_sparse(&sparse);
            let l1: f64 = sparse.iter().map(|(_, b)| b.abs()).sum();
            let primal = prob.primal_from_margins(&uu, l1, lam);
            let dual = prob.dual_value(&theta_feas, lam);
            gap = (primal - dual).max(0.0);
            if gap <= self.cfg.eps || outer >= self.cfg.max_outer {
                return BlitzResult {
                    beta: sparse,
                    gap,
                    outer_iters: outer,
                    epochs,
                    max_working,
                    secs: sw.secs(),
                };
            }
            budget = (budget * 2).min(p);
        }
    }
}

impl crate::solver::Solver for Blitz<'_> {
    fn name(&self) -> &'static str {
        "blitz"
    }

    /// BLITZ rebuilds its working set from the dual geometry each
    /// outer pass, so a warm β seed has nothing to attach to — the
    /// seed is ignored and `path()` is bitwise identical to
    /// independent per-λ solves.
    fn solve_warm(
        &mut self,
        prob: &Problem,
        lam: f64,
        _warm: Option<&[(usize, f64)]>,
    ) -> crate::solver::Solution {
        let r = self.solve(prob, lam);
        crate::solver::Solution {
            beta: r.beta,
            gap: r.gap,
            epochs: r.epochs,
            secs: r.secs,
            warm_started: false,
            stats: vec![
                ("outer_iters", r.outer_iters as f64),
                ("max_working", r.max_working as f64),
            ],
            trace: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cm::NativeEngine;
    use crate::data::synth;

    #[test]
    fn blitz_matches_saif_support() {
        let ds = synth::synth_linear(40, 300, 61);
        let prob = ds.problem();
        let lam = prob.lambda_max() * 0.1;
        let mut eng = NativeEngine::new();
        let mut blitz = Blitz::new(&mut eng, BlitzConfig { eps: 1e-9, ..Default::default() });
        let res = blitz.solve(&prob, lam);
        assert!(res.gap <= 1e-9, "gap {}", res.gap);
        assert!(prob.kkt_violation(&res.beta, lam) < 1e-3 * lam.max(1.0));

        let mut eng2 = NativeEngine::new();
        let mut saif = crate::saif::Saif::new(
            &mut eng2,
            crate::saif::SaifConfig { eps: 1e-9, ..Default::default() },
        );
        let sres = saif.solve(&prob, lam);
        let mut a: Vec<usize> = res.beta.iter().map(|&(i, _)| i).collect();
        let mut b: Vec<usize> = sres.beta.iter().map(|&(i, _)| i).collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn blitz_working_set_stays_small() {
        // near λ_max the active set is tiny and BLITZ must converge
        // without ever growing its working set to the full problem
        let ds = synth::synth_linear(50, 800, 63);
        let prob = ds.problem();
        let lam = prob.lambda_max() * 0.6;
        let mut eng = NativeEngine::new();
        let mut blitz = Blitz::new(&mut eng, BlitzConfig::default());
        let res = blitz.solve(&prob, lam);
        assert!(res.gap <= 1e-6);
        assert!(res.max_working < prob.p() / 2, "working {}", res.max_working);
        // harder λ may legitimately grow the budget, but must converge
        let lam2 = prob.lambda_max() * 0.3;
        let res2 = blitz.solve(&prob, lam2);
        assert!(res2.gap <= 1e-6);
        assert!(res2.max_working <= prob.p());
    }

    #[test]
    fn blitz_logistic_converges() {
        let ds = synth::gisette_like(50, 150, 65);
        let prob = ds.problem();
        let lam = prob.lambda_max() * 0.2;
        let mut eng = NativeEngine::new();
        let mut blitz = Blitz::new(&mut eng, BlitzConfig { eps: 1e-7, ..Default::default() });
        let res = blitz.solve(&prob, lam);
        assert!(res.gap <= 1e-7, "gap {}", res.gap);
    }
}
