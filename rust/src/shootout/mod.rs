//! Benchopt-style method shootout: every feature-LASSO method on one
//! shared scenario grid — {ls, logistic} × {dense, sparse, out-of-core}
//! designs plus an elastic-net LS row and a Huber row (both dense,
//! in-memory) — each solved over the same descending λ-path, recording
//! wall time and the HONEST (full-problem) certificate per grid point.
//!
//! The output is a flat JSON record (`BENCH_methods.json` at the repo
//! root, marker `"bench":"methods"`) in the same shape as the kernel
//! micro-bench record, so `tools/bench_guard.py` gates the `_secs`
//! rows against the committed baseline exactly like the kernel rows
//! (ooc rows excluded — disk timings are too noisy to gate on).
//! Time-to-gap curves ride along as `_curve_secs`/`_curve_gap` arrays,
//! unguarded.
//!
//! The structured-penalty methods (`fused`, `group`) are excluded on
//! purpose: they solve different objectives, so their timings are not
//! comparable on this grid.
//!
//! Entry points: `repro bench-methods [--quick]` and
//! `cargo bench --bench methods`.

use crate::cm::NativeEngine;
use crate::data::{synth, Dataset};
use crate::metrics::Table;
use crate::model::{LossKind, Penalty};
use crate::solver::{make, Method, SolveSpec, Solver};
use crate::util::json::Json;
use crate::util::{tmax, Stopwatch};

/// The comparable (feature-LASSO) method set, in table order.
pub const METHODS: &[Method] = &[
    Method::Saif,
    Method::DynScreen,
    Method::Blitz,
    Method::Homotopy,
    Method::GapSafe { dome: true, dynamic: true },
    Method::GapSafe { dome: false, dynamic: true },
    Method::GapSafe { dome: true, dynamic: false },
    Method::GapSafe { dome: false, dynamic: false },
    Method::Hybrid,
];

/// Stopping gap shared by every run (recorded in the JSON).
pub const EPS: f64 = 1e-6;

/// Where the record lands: the repo root, independent of the
/// invocation CWD (same convention as `BENCH_kernels.json`).
pub const RECORD_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_methods.json");

/// A finished shootout: the human-facing table and the machine record.
pub struct ShootoutResult {
    pub table: Table,
    pub record: Json,
}

/// JSON-key-safe method label: `Method::label` with `:` (a shell/JSON
/// annoyance in flat keys) mapped to `-`, e.g. `gapsafe:static` →
/// `gapsafe-static`.
pub fn key_label(method: Method) -> String {
    method.label().replace(':', "-")
}

/// Sparse logistic scenario: the sparse LS design with labels
/// thresholded to ±1 (there is no native sparse logistic generator).
fn sparse_logit(n: usize, p: usize, density: f64, seed: u64) -> Dataset {
    let mut ds = synth::synth_sparse(n, p, density, seed);
    for v in ds.y.iter_mut() {
        *v = if *v >= 0.0 { 1.0 } else { -1.0 };
    }
    ds.loss = LossKind::Logistic;
    ds.name = format!("{}-logit", ds.name);
    ds
}

/// Spill a dataset to a temp `.saifbin` and reopen it out-of-core; the
/// temp path is pushed onto `temp_paths` for the caller to unlink.
fn spill_ooc(ds: &Dataset, tag: &str, temp_paths: &mut Vec<String>) -> Result<Dataset, String> {
    let path = std::env::temp_dir().join(format!(
        "saif_shootout_{}_{tag}.saifbin",
        std::process::id()
    ));
    let path = path.to_str().ok_or("non-UTF-8 temp path")?.to_string();
    crate::data::io::write_saifbin(ds, &path)?;
    let ooc = crate::data::io::read_saifbin(&path)?;
    temp_paths.push(path);
    Ok(ooc)
}

/// Huber scenario: the dense LS design re-read under the robust loss
/// (δ = 1), exercising the loss-general screening path.
fn huber_dense(n: usize, p: usize, seed: u64) -> Dataset {
    let mut ds = synth::synth_linear(n, p, seed);
    ds.loss = LossKind::Huber { delta: 1.0 };
    ds.name = format!("{}-huber", ds.name);
    ds
}

/// The shared scenario grid, each row carrying its elastic-net penalty
/// ([`Penalty::default`] = pure ℓ1). `quick` shrinks the sizes and the
/// λ grid for smoke tests; full scale is what CI records.
fn scenarios(
    quick: bool,
    temp_paths: &mut Vec<String>,
) -> Result<Vec<(&'static str, Dataset, Penalty)>, String> {
    let (n_d, p_d, n_s, p_s, dens) = if quick {
        (60, 150, 80, 600, 0.02)
    } else {
        (100, 2000, 256, 10_000, 0.01)
    };
    let plain = Penalty::default();
    let ls_sparse = synth::synth_sparse(n_s, p_s, dens, 13);
    let logit_sparse = sparse_logit(n_s, p_s, dens, 14);
    let ls_ooc = spill_ooc(&ls_sparse, "ls", temp_paths)?;
    let logit_ooc = spill_ooc(&logit_sparse, "logit", temp_paths)?;
    Ok(vec![
        ("ls_dense", synth::synth_linear(n_d, p_d, 11), plain),
        ("logit_dense", synth::gisette_like(n_d, p_d, 12), plain),
        ("ls_sparse", ls_sparse, plain),
        ("logit_sparse", logit_sparse, plain),
        ("ls_ooc", ls_ooc, plain),
        ("logit_ooc", logit_ooc, plain),
        // the new loss × penalty axes (dense, in-memory only)
        ("enet_ls_dense", synth::synth_linear(n_d, p_d, 15), Penalty::ridge(0.1)),
        ("huber_dense", huber_dense(n_d, p_d, 16), plain),
    ])
}

/// Run the full shootout. Every method solves every scenario's λ-path
/// (0.5·λ_max down to 0.05·λ_max, log-spaced) on a fresh engine; per
/// (scenario, method) the record gets
///
/// * `<scenario>_<label>_secs` — path wall seconds (guarded by the
///   bench guard, ooc scenarios excluded),
/// * `<scenario>_<label>_gap` — worst per-point certificate on the
///   path (honest: for the unsafe homotopy baseline this can exceed
///   ε — that being visible is the point),
/// * `<scenario>_<label>_curve_secs` / `_curve_gap` — the time-to-gap
///   curve: cumulative seconds and certified gap at each grid point.
pub fn run(quick: bool) -> Result<ShootoutResult, String> {
    run_filtered(quick, None, None)
}

/// [`run`] restricted to the scenario rows matching a loss and/or an
/// exact ridge weight (the CLI's `--loss`/`--l2` filters on
/// `bench-methods`). An empty intersection is an error naming the
/// available rows, not an empty table.
pub fn run_filtered(
    quick: bool,
    loss: Option<LossKind>,
    l2: Option<f64>,
) -> Result<ShootoutResult, String> {
    let n_lams = if quick { 3 } else { 8 };
    let mut temp_paths = Vec::new();
    let result = run_inner(quick, n_lams, loss, l2, &mut temp_paths);
    // cleanup on success AND on every early-return error path
    for p in &temp_paths {
        std::fs::remove_file(p).ok();
    }
    result
}

fn run_inner(
    quick: bool,
    n_lams: usize,
    loss: Option<LossKind>,
    l2: Option<f64>,
    temp_paths: &mut Vec<String>,
) -> Result<ShootoutResult, String> {
    let all = scenarios(quick, temp_paths)?;
    let names: Vec<&str> = all.iter().map(|(k, _, _)| *k).collect();
    let scens: Vec<_> = all
        .into_iter()
        .filter(|(_, ds, pen)| {
            loss.map_or(true, |l| ds.loss == l) && l2.map_or(true, |w| pen.l2 == w)
        })
        .collect();
    if scens.is_empty() {
        return Err(format!(
            "no scenario rows match the loss/l2 filter; rows: {}",
            names.join(", ")
        ));
    }
    let mut rec = Json::obj();
    rec.set("bench", Json::Str("methods".into()))
        .set("n_lambdas", Json::Num(n_lams as f64))
        .set("eps", Json::Num(EPS))
        .set("quick", Json::Bool(quick));
    let mut table = Table::new(
        "method shootout: λ-path wall time + honest certificates",
        &["scenario", "method", "secs", "worst_gap", "final_nnz"],
    );
    for (key, ds, penalty) in &scens {
        let prob = ds.problem();
        let lam_max = prob.lambda_max();
        let denom = (n_lams - 1).max(1) as f64;
        let grid: Vec<f64> = (0..n_lams)
            .map(|k| lam_max * 0.5 * (0.1f64).powf(k as f64 / denom))
            .collect();
        for &method in METHODS {
            let label = key_label(method);
            let spec = SolveSpec { eps: EPS, penalty: *penalty, ..Default::default() };
            let mut eng = NativeEngine::new();
            let sw = Stopwatch::start();
            let path = make(method, &mut eng, &spec).path(&prob, &grid);
            let secs = sw.secs();
            let worst_gap = path.points.iter().map(|s| s.gap).fold(0.0, tmax);
            let mut cum = 0.0;
            let curve_secs: Vec<Json> = path
                .points
                .iter()
                .map(|s| {
                    cum += s.secs;
                    Json::Num(cum)
                })
                .collect();
            let curve_gap: Vec<Json> =
                path.points.iter().map(|s| Json::Num(s.gap)).collect();
            rec.set(&format!("{key}_{label}_secs"), Json::Num(secs))
                .set(&format!("{key}_{label}_gap"), Json::Num(worst_gap))
                .set(&format!("{key}_{label}_curve_secs"), Json::Arr(curve_secs))
                .set(&format!("{key}_{label}_curve_gap"), Json::Arr(curve_gap));
            let final_nnz = path.points.last().map(|s| s.beta.len()).unwrap_or(0);
            table.row(vec![
                key.to_string(),
                method.label(),
                format!("{secs:.4}"),
                format!("{worst_gap:.2e}"),
                final_nnz.to_string(),
            ]);
        }
    }
    Ok(ShootoutResult { table, record: rec })
}

/// Write the record to [`RECORD_PATH`]; returns the path written.
pub fn write_record(record: &Json) -> Result<&'static str, String> {
    std::fs::write(RECORD_PATH, record.to_string() + "\n")
        .map(|_| RECORD_PATH)
        .map_err(|e| format!("write {RECORD_PATH}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_labels_are_json_flat_key_safe_and_unique() {
        let mut labels: Vec<String> = METHODS.iter().map(|&m| key_label(m)).collect();
        for l in &labels {
            assert!(!l.contains(':'), "{l}");
            assert!(!l.is_empty());
        }
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), METHODS.len(), "duplicate method labels");
    }

    #[test]
    fn quick_shootout_covers_the_full_grid_with_finite_numbers() {
        let res = run(true).expect("quick shootout");
        assert_eq!(res.record.get("bench").and_then(|v| v.as_str()), Some("methods"));
        let scen_keys = [
            "ls_dense",
            "logit_dense",
            "ls_sparse",
            "logit_sparse",
            "ls_ooc",
            "logit_ooc",
            "enet_ls_dense",
            "huber_dense",
        ];
        for scen in scen_keys {
            for &m in METHODS {
                let label = key_label(m);
                let secs = res
                    .record
                    .get(&format!("{scen}_{label}_secs"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or_else(|| panic!("missing {scen}_{label}_secs"));
                assert!(secs.is_finite() && secs >= 0.0, "{scen}/{label}: {secs}");
                let gap = res
                    .record
                    .get(&format!("{scen}_{label}_gap"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or_else(|| panic!("missing {scen}_{label}_gap"));
                assert!(gap.is_finite(), "{scen}/{label}: gap {gap}");
                let curve = res
                    .record
                    .get(&format!("{scen}_{label}_curve_gap"))
                    .and_then(|v| v.as_arr())
                    .unwrap_or_else(|| panic!("missing {scen}_{label}_curve_gap"));
                assert_eq!(curve.len(), 3, "{scen}/{label}");
            }
        }
        // the record round-trips through the parser the guard's json
        // module mirrors
        let back = Json::parse(&res.record.to_string()).expect("record parses");
        assert_eq!(back, res.record);
        // 8 scenarios × all methods in the table
        // (header is not a row; Table::row count is rows only)
        assert!(res.table.rows.len() == scen_keys.len() * METHODS.len());
    }

    #[test]
    fn safe_methods_certify_on_the_quick_grid() {
        // every SAFE method's worst path gap stays ≤ ε on the quick
        // grid; homotopy (unsafe) is exempt — its honest gap may
        // legitimately exceed ε, which is exactly what the record is
        // for.
        let res = run(true).expect("quick shootout");
        for scen in ["ls_dense", "logit_dense", "ls_sparse", "enet_ls_dense", "huber_dense"] {
            for &m in METHODS {
                if m == Method::Homotopy {
                    continue;
                }
                let label = key_label(m);
                let gap = res
                    .record
                    .get(&format!("{scen}_{label}_gap"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::NAN);
                assert!(gap <= EPS * 1.01, "{scen}/{label}: worst gap {gap}");
            }
        }
    }

    #[test]
    fn loss_and_l2_filters_restrict_the_grid() {
        // huber filter keeps exactly the huber row
        let res =
            run_filtered(true, Some(LossKind::Huber { delta: 1.0 }), None).expect("huber row");
        assert_eq!(res.table.rows.len(), METHODS.len());
        let rendered = res.table.render();
        assert!(rendered.contains("huber_dense"), "{rendered}");
        assert!(!rendered.contains("ls_dense"), "{rendered}");
        // l2 filter keeps exactly the elastic-net row
        let res = run_filtered(true, None, Some(0.1)).expect("enet row");
        assert_eq!(res.table.rows.len(), METHODS.len());
        assert!(res.table.render().contains("enet_ls_dense"));
        // an empty intersection is an error naming the rows
        let err = run_filtered(true, Some(LossKind::SquaredHinge), None).unwrap_err();
        assert!(err.contains("huber_dense") && err.contains("ls_dense"), "{err}");
    }
}
