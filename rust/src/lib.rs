//! # SAIF — Safe Active Feature Selection for Sparse Learning
//!
//! Reproduction of Ren, Huang, Huang & Qian (2018): *Safe Active
//! Incremental Feature selection* for LASSO and tree fused LASSO, as a
//! three-layer rust + JAX/Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   SAIF outer loop ([`saif`]), ball regions ([`ball`]), the baseline
//!   algorithms it is evaluated against ([`screening`], [`homotopy`],
//!   [`workingset`]), the fused-LASSO tree transform ([`fused`]), a
//!   unified solver API with first-class λ-path sessions ([`solver`]),
//!   a benchopt-style method shootout ([`shootout`]), and a
//!   multi-tenant solve-request coordinator ([`coordinator`]) with a
//!   TCP serving front-end over it ([`serve`]).
//! * **L2/L1 (python/compile, build time only)** — JAX graphs + Pallas
//!   kernels for the numeric inner loop, AOT-lowered to HLO text.
//! * **Runtime bridge** ([`runtime`]) — loads the AOT artifacts via the
//!   PJRT CPU client (`xla` crate) so Python is never on the request
//!   path, and hosts [`runtime::pool`], the persistent deterministic
//!   worker pool every parallel path (chunked scans, sharded epochs,
//!   coordinator workers) dispatches through. The native f64 engine
//!   ([`cm::NativeEngine`]) implements the identical semantics for
//!   cross-checking and for sizes beyond the artifact shape buckets.
//!
//! See DESIGN.md for the full system inventory and EXPERIMENTS.md for
//! the paper-vs-measured reproduction record.

// Every `unsafe` in this crate (all of it lives in `runtime::pool`'s
// lifetime-erasure plumbing) must carry its own `// SAFETY:` argument,
// and unsafe fns get no blanket license for unsafe ops in their bodies.
// `tools/vet` enforces the same contract toolchain-independently; see
// docs/INVARIANTS.md.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod ball;
pub mod cli;
pub mod cm;
pub mod coordinator;
pub mod cv;
pub mod data;
pub mod experiments;
pub mod fused;
pub mod homotopy;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod saif;
pub mod screening;
pub mod serve;
pub mod shootout;
pub mod solver;
pub mod util;
pub mod workingset;
