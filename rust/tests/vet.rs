//! Meta-test: the source tree this crate ships must pass its own
//! invariant linter (`tools/vet`) with zero findings — every waiver in
//! the tree is therefore known-used and carries a reason, and a change
//! that introduces a raw spawn / undocumented unsafe / unordered map /
//! NaN-lossy comparison / bare cast / library panic / stray f32 in the
//! solver stack fails `cargo test` locally, not just the separate CI
//! job.

/// Shelling out to `cargo run` is host-only: Miri interprets the test
/// body and cannot exec the build toolchain.
#[cfg(not(miri))]
#[test]
fn source_tree_passes_vet() {
    let manifest_dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let repo_root = manifest_dir.parent().expect("crate lives one level under the repo root");
    let vet_manifest = repo_root.join("tools").join("vet").join("Cargo.toml");
    assert!(
        vet_manifest.is_file(),
        "vet crate missing at {}",
        vet_manifest.display()
    );
    let cargo = std::env::var("CARGO").unwrap_or_else(|_| "cargo".to_string());
    let out = std::process::Command::new(cargo)
        .arg("run")
        .arg("--quiet")
        .arg("--manifest-path")
        .arg(&vet_manifest)
        .arg("--")
        .arg(manifest_dir.join("src"))
        .output()
        .expect("build and run the vet binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "vet found invariant violations in rust/src \
         (fix them or add a `// vet: allow(<lint>): <reason>` waiver):\n{stdout}\n{stderr}"
    );
}
