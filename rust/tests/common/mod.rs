//! Shared KKT-certificate test oracle.
//!
//! Every safe-screening claim in this repo bottoms out in the same
//! three checks: (1) the subgradient residual of the returned β on the
//! FULL problem is within tolerance (the safety certificate), (2) the
//! reported duality gap met the requested ε, and (3) when a reference
//! solve exists, the supports (and coefficients) match. These used to
//! be re-implemented inline per test file; this module is the single
//! implementation, usable both from `assert!`-style tests (the
//! `assert_*` wrappers panic) and from `util::prop` property closures
//! (the `check_*` functions return `Result<(), String>` for `?`).
//!
//! `#![allow(dead_code)]`: each integration-test binary compiles this
//! module independently and uses a different subset of the oracle.
#![allow(dead_code)]

use saif::cm::PoolMode;
use saif::linalg::Parallelism;
use saif::model::Problem;
use saif::util::prop;

/// Default relative KKT tolerance: a solve converged to a ~1e-9 gap
/// certifies at ≲1e-3·max(λ, 1) subgradient residual (the scale the
/// repo's tests have always used for f64 engines).
pub const KKT_REL_TOL: f64 = 1e-3;

/// Default |β| threshold below which a coefficient does not count as
/// support (numerical zeros from soft-thresholding near convergence).
pub const SUPPORT_TOL: f64 = 1e-7;

/// Subgradient-residual check (the safety certificate): the worst KKT
/// violation of `beta` on the FULL problem must be below
/// `rel_tol · max(λ, 1)`.
pub fn check_kkt(
    prob: &Problem,
    beta: &[(usize, f64)],
    lam: f64,
    rel_tol: f64,
) -> Result<(), String> {
    let viol = prob.kkt_violation(beta, lam);
    if viol > rel_tol * lam.max(1.0) {
        return Err(format!(
            "KKT violation {viol:.3e} > {rel_tol:.0e}·max(λ,1) at λ={lam:.3e}"
        ));
    }
    Ok(())
}

/// Duality-gap check: the solver must have reached the ε it was asked
/// for, and a gap can never be negative.
pub fn check_gap(gap: f64, eps: f64) -> Result<(), String> {
    if gap < 0.0 {
        return Err(format!("negative duality gap {gap:.3e}"));
    }
    if gap > eps {
        return Err(format!("duality gap {gap:.3e} > requested ε {eps:.0e}"));
    }
    Ok(())
}

/// Support of a sparse β (sorted indices with |β_i| > tol).
pub fn support_sparse(beta: &[(usize, f64)], tol: f64) -> Vec<usize> {
    let mut s: Vec<usize> =
        beta.iter().filter(|(_, b)| b.abs() > tol).map(|&(i, _)| i).collect();
    s.sort_unstable();
    s
}

/// Support of a dense β (sorted indices with |β_i| > tol).
pub fn support_dense(beta: &[f64], tol: f64) -> Vec<usize> {
    (0..beta.len()).filter(|&i| beta[i].abs() > tol).collect()
}

/// Support-match check between two sparse solutions.
pub fn check_supports_match(
    a: &[(usize, f64)],
    b: &[(usize, f64)],
    tol: f64,
    what: &str,
) -> Result<(), String> {
    let (sa, sb) = (support_sparse(a, tol), support_sparse(b, tol));
    if sa != sb {
        return Err(format!("{what}: supports differ: {sa:?} vs {sb:?}"));
    }
    Ok(())
}

/// Coefficient-match check of a sparse solution against a dense
/// reference (per-coefficient `prop::assert_close` semantics).
pub fn check_coeffs_match(
    beta: &[(usize, f64)],
    reference: &[f64],
    atol: f64,
    rtol: f64,
) -> Result<(), String> {
    for &(i, b) in beta {
        prop::assert_close(b, reference[i], atol, rtol, &format!("β[{i}]"))?;
    }
    Ok(())
}

/// The full certificate: gap reached ε AND the subgradient residual
/// certifies optimality on the full problem.
pub fn check_certificate(
    prob: &Problem,
    beta: &[(usize, f64)],
    lam: f64,
    gap: f64,
    eps: f64,
) -> Result<(), String> {
    check_gap(gap, eps)?;
    check_kkt(prob, beta, lam, KKT_REL_TOL)
}

/// Panicking wrapper of [`check_certificate`] for `#[test]` bodies.
pub fn assert_certificate(prob: &Problem, beta: &[(usize, f64)], lam: f64, gap: f64, eps: f64) {
    if let Err(msg) = check_certificate(prob, beta, lam, gap, eps) {
        panic!("certificate failed: {msg}");
    }
}

/// Panicking wrapper of [`check_kkt`] at the default tolerance.
pub fn assert_kkt(prob: &Problem, beta: &[(usize, f64)], lam: f64) {
    if let Err(msg) = check_kkt(prob, beta, lam, KKT_REL_TOL) {
        panic!("certificate failed: {msg}");
    }
}

/// Scan parallelism for the test run, from `SAIF_TEST_THREADS`
/// ("serial"/"auto"/N — see `Parallelism::parse`; unset ⇒ serial).
/// `ci.sh` runs the suite once with 1 and once with 4 so the sharded
/// epoch + parallel scan paths are exercised in tier-1.
pub fn test_parallelism() -> Parallelism {
    match std::env::var("SAIF_TEST_THREADS") {
        Ok(s) => Parallelism::parse(&s)
            .unwrap_or_else(|| panic!("bad SAIF_TEST_THREADS value '{s}'")),
        Err(_) => Parallelism::Serial,
    }
}

/// Threading substrate for the test run, from `SAIF_TEST_POOL`
/// ("persistent"/"scoped" — see `PoolMode::parse`; unset ⇒ the
/// default, persistent). `ci.sh` runs the threaded suite once per
/// mode so both substrates are exercised in tier-1.
pub fn test_pool_mode() -> PoolMode {
    match std::env::var("SAIF_TEST_POOL") {
        Ok(s) => {
            PoolMode::parse(&s).unwrap_or_else(|| panic!("bad SAIF_TEST_POOL value '{s}'"))
        }
        Err(_) => PoolMode::default(),
    }
}
