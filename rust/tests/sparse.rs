//! Sparse-design parity: the CSC backend must be numerically
//! indistinguishable from the dense backend on every kernel the
//! solvers use, and a libsvm file loaded sparse (never densified) must
//! solve through SAIF, dynamic screening and BLITZ with a full KKT
//! certificate.

mod common;

use saif::cm::NativeEngine;
use saif::data::{io, synth};
use saif::linalg::{CscMat, Design, Parallelism};
use saif::runtime::pool::PoolMode;
use saif::model::Problem;
use saif::saif::{Saif, SaifConfig};
use saif::screening::dynamic::{DynScreen, DynScreenConfig};
use saif::util::prop;
use saif::workingset::{Blitz, BlitzConfig};

/// Random sparse/dense design pair with identical entries.
fn random_designs(rng: &mut saif::util::Rng, n: usize, p: usize) -> (Design, Design) {
    let mut cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(p);
    for _ in 0..p {
        let nnz = 1 + rng.below(n.min(10));
        cols.push(
            rng.sample_indices(n, nnz)
                .into_iter()
                .map(|i| (i, rng.normal()))
                .collect(),
        );
    }
    let sp = CscMat::from_cols(n, cols);
    let dn = sp.to_dense();
    (Design::Sparse(sp), Design::Dense(dn))
}

#[test]
fn sparse_dense_kernel_parity() {
    prop::check("sparse == dense kernels", 16, |rng| {
        let n = 10 + rng.below(40);
        let p = 5 + rng.below(80);
        let (sp, dn) = random_designs(rng, n, p);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (mut a, mut b) = (vec![0.0; p], vec![0.0; p]);
        sp.mul_t_vec(&v, &mut a);
        dn.mul_t_vec(&v, &mut b);
        prop::assert_slice_close(&a, &b, 1e-12, 1e-12, "mul_t_vec")?;
        let mut c = vec![0.0; p];
        sp.mul_t_vec_pool(&v, &mut c, Parallelism::Fixed(4), PoolMode::Scoped);
        if a != c {
            return Err("parallel scan differs from serial".into());
        }
        prop::assert_slice_close(
            &sp.col_norms_sq(),
            &dn.col_norms_sq(),
            1e-12,
            1e-12,
            "col_norms_sq",
        )?;
        for j in 0..p {
            prop::assert_close(sp.col_dot(j, &v), dn.col_dot(j, &v), 1e-12, 1e-12, "col_dot")?;
        }
        Ok(())
    });
}

#[test]
fn sparse_dense_problem_parity() {
    prop::check("sparse == dense lambda_max/init_corrs", 10, |rng| {
        let n = 20 + rng.below(40);
        let p = 30 + rng.below(100);
        let (sp, dn) = random_designs(rng, n, p);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let ps = Problem::new(sp, y.clone(), saif::model::LossKind::Squared);
        let pd = Problem::new(dn, y, saif::model::LossKind::Squared);
        prop::assert_close(ps.lambda_max(), pd.lambda_max(), 1e-12, 1e-12, "lambda_max")?;
        prop::assert_slice_close(&ps.init_corrs(), &pd.init_corrs(), 1e-12, 1e-12, "init_corrs")?;
        prop::assert_slice_close(
            &ps.init_corrs_par(Parallelism::Fixed(3)),
            &pd.init_corrs(),
            1e-12,
            1e-12,
            "init_corrs_par",
        )?;
        Ok(())
    });
}

#[test]
fn sparse_dense_saif_solutions_agree() {
    prop::check("sparse == dense SAIF solve", 6, |rng| {
        let n = 40 + rng.below(30);
        let p = 200 + rng.below(200);
        let density = 0.05 + 0.1 * rng.uniform();
        let ds = synth::synth_sparse(n, p, density, rng.next_u64());
        let sparse_prob = ds.problem();
        let dense_prob = Problem::new(ds.x.to_dense(), ds.y.clone(), ds.loss);
        let lam = sparse_prob.lambda_max() * (0.05 + 0.2 * rng.uniform());

        let mut e1 = NativeEngine::new();
        let rs = Saif::new(&mut e1, SaifConfig { eps: 1e-12, ..Default::default() })
            .solve(&sparse_prob, lam);
        let mut e2 = NativeEngine::new();
        let rd = Saif::new(&mut e2, SaifConfig { eps: 1e-12, ..Default::default() })
            .solve(&dense_prob, lam);

        common::check_supports_match(&rs.beta, &rd.beta, 1e-10, "sparse vs dense")?;
        let dmap: std::collections::HashMap<usize, f64> = rd.beta.iter().cloned().collect();
        for &(i, b) in &rs.beta {
            let d = dmap.get(&i).copied().unwrap_or(0.0);
            prop::assert_close(b, d, 1e-8, 1e-8, &format!("β[{i}]"))?;
        }
        // certificate on the sparse problem
        common::check_kkt(&sparse_prob, &rs.beta, lam, common::KKT_REL_TOL)?;
        Ok(())
    });
}

#[test]
fn libsvm_sparse_load_solves_all_safe_methods() {
    let ds = synth::synth_sparse(60, 600, 0.03, 991);
    let path = std::env::temp_dir().join("saif_sparse_e2e.svm");
    let path = path.to_str().unwrap();
    io::write_libsvm(&ds, path).unwrap();
    let back = io::read_libsvm(path, false).unwrap();
    std::fs::remove_file(path).ok();
    assert!(back.x.is_sparse(), "libsvm load must not densify");
    assert_eq!(back.p(), ds.p(), "dimension lost on roundtrip");
    assert_eq!(back.n(), ds.n());

    let prob = back.problem();
    let lam = prob.lambda_max() * 0.1;
    let eps = 1e-9;

    let mut e1 = NativeEngine::new();
    let saif_res =
        Saif::new(&mut e1, SaifConfig { eps, ..Default::default() }).solve(&prob, lam);
    common::assert_certificate(&prob, &saif_res.beta, lam, saif_res.gap, eps);
    // SAIF on sparse text-like data must keep the active set small —
    // the workload class the paper's scalability claim targets
    assert!(saif_res.max_active < prob.p() / 4);

    let mut e2 = NativeEngine::new();
    let dyn_res = DynScreen::new(&mut e2, DynScreenConfig { eps, ..Default::default() })
        .solve(&prob, lam);
    common::assert_kkt(&prob, &dyn_res.beta, lam);

    let mut e3 = NativeEngine::new();
    let blitz_res =
        Blitz::new(&mut e3, BlitzConfig { eps, ..Default::default() }).solve(&prob, lam);
    common::assert_kkt(&prob, &blitz_res.beta, lam);

    // all three agree on the support
    common::check_supports_match(
        &saif_res.beta,
        &dyn_res.beta,
        common::SUPPORT_TOL,
        "saif vs dyn",
    )
    .unwrap();
    common::check_supports_match(
        &saif_res.beta,
        &blitz_res.beta,
        common::SUPPORT_TOL,
        "saif vs blitz",
    )
    .unwrap();
}

#[test]
fn parallel_saif_matches_serial() {
    use saif::cm::EpochShards;
    let ds = synth::synth_sparse(50, 1000, 0.02, 4242);
    let prob = ds.problem();
    let lam = prob.lambda_max() * 0.1;
    let mut e1 = NativeEngine::new();
    let serial = Saif::new(&mut e1, SaifConfig { eps: 1e-10, ..Default::default() })
        .solve(&prob, lam);
    // chunked scans are bitwise-identical to serial; epochs are pinned
    // serial (shards=1) so the whole solve trajectory matches bitwise
    // even though --threads normally shards wide epochs too
    let mut e2 = NativeEngine::new();
    let parallel = Saif::new(
        &mut e2,
        SaifConfig {
            eps: 1e-10,
            parallelism: Some(Parallelism::Fixed(4)),
            epoch_shards: Some(EpochShards::Fixed(1)),
            ..Default::default()
        },
    )
    .solve(&prob, lam);
    assert_eq!(serial.beta, parallel.beta);
    assert_eq!(serial.outer_iters, parallel.outer_iters);

    // with sharded epochs the trajectory may differ, but the result
    // must still carry the full certificate and the same support
    let mut e3 = NativeEngine::new();
    let sharded = Saif::new(
        &mut e3,
        SaifConfig {
            eps: 1e-10,
            parallelism: Some(Parallelism::Fixed(4)),
            epoch_shards: Some(EpochShards::Fixed(4)),
            ..Default::default()
        },
    )
    .solve(&prob, lam);
    common::assert_certificate(&prob, &sharded.beta, lam, sharded.gap, 1e-10);
    common::check_supports_match(
        &serial.beta,
        &sharded.beta,
        common::SUPPORT_TOL,
        "serial vs sharded epochs",
    )
    .unwrap();
}
