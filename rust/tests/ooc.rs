//! Out-of-core parity: a design streamed from a `.saifbin` file must
//! be a pure storage swap. Every kernel, every scan substrate and
//! every solve produces the SAME BITS as the equivalent in-memory
//! `Sparse` design — dense and sparse seeds, least-squares and
//! logistic losses, persistent and scoped pool modes — and the
//! coordinator serves a path-registered `.saifbin` dataset end to end
//! with certified responses identical to in-memory serving.
//!
//! Miri: the interpreter has no positional file reads, so under
//! `cfg(miri)` the spill helper round-trips through the in-memory
//! `.saifbin` byte image (`saifbin_bytes` → `read_saifbin_bytes`)
//! instead of a temp file — same header validation, same streaming
//! kernels, byte-identical image. The kernel-parity property runs
//! under Miri at reduced size; the full-solve/coordinator tests are
//! host-only (hours-scale under interpretation, and they only add
//! solver iterations on top of the same kernels).

mod common;

#[cfg(not(miri))]
use saif::cm::EpochShards;
use saif::cm::PoolMode;
use saif::coordinator::{Coordinator, CoordinatorError, Method, SolveSpec};
#[cfg(miri)]
use saif::data::io::{read_saifbin_bytes, saifbin_bytes};
#[cfg(not(miri))]
use saif::data::io::{read_saifbin, write_saifbin};
use saif::data::{synth, Dataset};
#[cfg(not(miri))]
use saif::linalg::OocCsc;
use saif::linalg::{CscMat, Design, Parallelism};
use saif::model::LossKind;
#[cfg(not(miri))]
use saif::model::Problem;
#[cfg(not(miri))]
use saif::solver::{make, Solver};
use saif::util::prop;
use saif::util::Rng;

/// Unique temp path per (test, tag) so parallel test binaries and
/// repeated runs never collide.
#[cfg(not(miri))]
fn tmp(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("saif_ooc_it_{}_{tag}.saifbin", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// Random dataset over {dense, sparse} seeds × {ls, logistic}. The
/// in-memory reference design is CSC either way (the acceptance
/// criterion is parity with the in-memory `Sparse` backend; a dense
/// seed just produces a CSC with ~no implicit zeros). Sizes shrink
/// under Miri — interpretation is ~3 orders of magnitude slower.
fn random_dataset(rng: &mut Rng, dense_seed: bool, logistic: bool) -> Dataset {
    let (n, p) = if cfg!(miri) {
        (6 + rng.below(6), 14 + rng.below(10))
    } else {
        (20 + rng.below(30), 80 + rng.below(120))
    };
    let mut ds = if dense_seed {
        let mut d = synth::synth_linear(n, p, rng.next_u64());
        d.x = Design::Sparse(CscMat::from_dense(&d.x.to_dense()));
        d
    } else {
        synth::synth_sparse(n, p, 0.05 + 0.15 * rng.uniform(), rng.next_u64())
    };
    if logistic {
        ds.y = ds.y.iter().map(|v| if *v >= 0.0 { 1.0 } else { -1.0 }).collect();
        ds.loss = LossKind::Logistic;
    }
    ds
}

/// A dataset spilled to `.saifbin` storage and reopened out-of-core.
/// Dropping it removes the backing temp file (when there is one).
struct Spilled {
    ds: Dataset,
    /// `None` under Miri (byte-backed, nothing to clean up).
    path: Option<String>,
}

impl Drop for Spilled {
    fn drop(&mut self) {
        if let Some(p) = &self.path {
            std::fs::remove_file(p).ok();
        }
    }
}

/// Spill `ds` to `.saifbin` storage and reopen it out-of-core: a temp
/// file on the host, the in-memory byte image under Miri.
#[cfg(not(miri))]
fn spill(ds: &Dataset, tag: &str) -> Spilled {
    let path = tmp(tag);
    write_saifbin(ds, &path).expect("write saifbin");
    let ds = read_saifbin(&path).expect("read saifbin");
    Spilled { ds, path: Some(path) }
}

#[cfg(miri)]
fn spill(ds: &Dataset, _tag: &str) -> Spilled {
    let ds = read_saifbin_bytes(saifbin_bytes(ds)).expect("read saifbin bytes");
    Spilled { ds, path: None }
}

#[test]
fn kernels_bitwise_match_in_memory_sparse() {
    let cases = if cfg!(miri) { 2 } else { 6 };
    prop::check("ooc kernels == in-memory CSC bitwise", cases, |rng| {
        let dense_seed = rng.uniform() > 0.5;
        let ds = random_dataset(rng, dense_seed, false);
        let (n, p) = (ds.n(), ds.p());
        let tag = format!("kern{}", rng.below(1 << 30));
        let spilled = spill(&ds, &tag);
        let (mem, ooc) = (&ds.x, &spilled.ds.x);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w: Vec<f64> = (0..p).map(|_| rng.normal()).collect();

        if ooc.nnz() != mem.nnz() {
            return Err(format!("nnz {} vs {}", ooc.nnz(), mem.nnz()));
        }
        for j in 0..p {
            let (a, b) = (ooc.col_dot(j, &v), mem.col_dot(j, &v));
            if a.to_bits() != b.to_bits() {
                return Err(format!("col_dot {j}: {a} vs {b}"));
            }
            let (mut xa, mut xb) = (v.clone(), v.clone());
            ooc.col_axpy(-1.7, j, &mut xa);
            mem.col_axpy(-1.7, j, &mut xb);
            if xa != xb {
                return Err(format!("col_axpy {j}"));
            }
            // col_iter yields the same stored entries
            let ia: Vec<(usize, f64)> = ooc.col_iter(j).collect();
            let ib: Vec<(usize, f64)> = mem.col_iter(j).collect();
            if ia != ib {
                return Err(format!("col_iter {j}"));
            }
        }
        // serial scan
        let (mut sa, mut sb) = (vec![0.0; p], vec![0.0; p]);
        ooc.mul_t_vec(&v, &mut sa);
        mem.mul_t_vec(&v, &mut sb);
        if sa != sb {
            return Err("mul_t_vec".into());
        }
        // pooled/scoped streaming scans, several widths (one width
        // under Miri: thread machinery is what's being checked there,
        // not chunking-geometry coverage)
        let widths: &[usize] = if cfg!(miri) { &[2] } else { &[2, 3, 7] };
        for &threads in widths {
            for mode in [PoolMode::Persistent, PoolMode::Scoped] {
                let mut pa = vec![0.0; p];
                ooc.mul_t_vec_pool(&v, &mut pa, Parallelism::Fixed(threads), mode);
                if pa != sb {
                    return Err(format!("pooled scan threads={threads} mode={mode:?}"));
                }
            }
        }
        // forward product, norms, batched ops, gathers
        let (mut ya, mut yb) = (vec![0.0; n], vec![0.0; n]);
        ooc.mul_vec(&w, &mut ya);
        mem.mul_vec(&w, &mut yb);
        if ya != yb {
            return Err("mul_vec".into());
        }
        if ooc.col_norms_sq() != mem.col_norms_sq() {
            return Err("col_norms_sq".into());
        }
        let cols: Vec<usize> = (0..6).map(|_| rng.below(p)).collect();
        let (mut ba, mut bb) = (vec![0.0; cols.len()], vec![0.0; cols.len()]);
        ooc.cols_dot(&cols, &v, &mut ba);
        mem.cols_dot(&cols, &v, &mut bb);
        if ba != bb {
            return Err("cols_dot".into());
        }
        let updates = [(cols[0], 0.5), (cols[1], -1.25), (cols[0], 0.75)];
        let (mut fa, mut fb) = (v.clone(), v.clone());
        ooc.cols_axpy(&updates, &mut fa);
        mem.cols_axpy(&updates, &mut fb);
        if fa != fb {
            return Err("cols_axpy".into());
        }
        let sel = ooc.select_cols(&cols);
        for (k, &j) in cols.iter().enumerate() {
            for i in 0..n {
                if sel.get(i, k).to_bits() != mem.get(i, j).to_bits() {
                    return Err(format!("select_cols ({i},{j})"));
                }
            }
        }
        let rows: Vec<usize> = (0..n / 2).map(|_| rng.below(n)).collect();
        let (ra, rb) = (ooc.select_rows(&rows), mem.select_rows(&rows));
        for j in 0..p {
            for (new, _) in rows.iter().enumerate() {
                if ra.get(new, j).to_bits() != rb.get(new, j).to_bits() {
                    return Err(format!("select_rows ({new},{j})"));
                }
            }
        }
        Ok(())
    });
}

/// The acceptance criterion: solves on a `.saifbin` design are
/// bitwise identical to the same solves on the in-memory sparse
/// design — dense + sparse seeds × ls/logistic × both pool modes,
/// with the KKT oracle certifying both sides.
#[cfg(not(miri))]
#[test]
fn solves_bitwise_match_in_memory_sparse() {
    let par = common::test_parallelism();
    let mut case = 0;
    for dense_seed in [false, true] {
        for logistic in [false, true] {
            let mut rng = Rng::new(7000 + case);
            case += 1;
            let ds = random_dataset(&mut rng, dense_seed, logistic);
            let spilled = spill(&ds, &format!("solve{case}"));
            let prob_mem = ds.problem();
            let prob_ooc = spilled.ds.problem();
            // cached column norms must match bitwise before anything
            // else (they seed every screening bound)
            assert_eq!(
                prob_mem.col_nrm2, prob_ooc.col_nrm2,
                "col_nrm2 differs (dense_seed={dense_seed})"
            );
            let lam = prob_mem.lambda_max() * 0.15;
            let eps = 1e-9;
            for mode in [PoolMode::Persistent, PoolMode::Scoped] {
                let solve = |prob: &Problem| {
                    let mut eng = saif::cm::NativeEngine::new();
                    let spec = SolveSpec {
                        eps,
                        parallelism: Some(par),
                        epoch_shards: Some(EpochShards::Fixed(2)),
                        pool: Some(mode),
                        ..Default::default()
                    };
                    let mut s = make(Method::Saif, &mut eng, &spec);
                    let sol = s.solve(prob, lam);
                    (sol.beta, sol.gap)
                };
                let (beta_mem, gap_mem) = solve(&prob_mem);
                let (beta_ooc, gap_ooc) = solve(&prob_ooc);
                assert_eq!(
                    beta_mem, beta_ooc,
                    "β differs (dense_seed={dense_seed}, logistic={logistic}, mode={mode:?})"
                );
                assert_eq!(gap_mem.to_bits(), gap_ooc.to_bits(), "gap bits differ");
                // both certify on the FULL problem via the shared oracle
                common::assert_certificate(&prob_mem, &beta_mem, lam, gap_mem, eps);
                common::assert_certificate(&prob_ooc, &beta_ooc, lam, gap_ooc, eps);
            }
        }
    }
}

/// λ-path sessions stream the same bits too (warm chaining reuses the
/// out-of-core design across the whole descending grid).
#[cfg(not(miri))]
#[test]
fn paths_bitwise_match_in_memory_sparse() {
    let mut rng = Rng::new(7100);
    let ds = random_dataset(&mut rng, false, false);
    let spilled = spill(&ds, "path");
    let prob_mem = ds.problem();
    let prob_ooc = spilled.ds.problem();
    let lam_max = prob_mem.lambda_max();
    let grid: Vec<f64> = (1..=6).map(|k| lam_max * 0.6f64.powi(k)).collect();
    for method in [Method::Saif, Method::DynScreen] {
        let run = |prob: &Problem| {
            let mut eng = saif::cm::NativeEngine::new();
            let spec = SolveSpec { eps: 1e-9, ..Default::default() };
            let mut s = make(method, &mut eng, &spec);
            s.path(prob, &grid)
        };
        let (pm, po) = (run(&prob_mem), run(&prob_ooc));
        for (k, (a, b)) in pm.points.iter().zip(&po.points).enumerate() {
            assert_eq!(a.beta, b.beta, "{method:?} path point {k} differs");
            common::assert_kkt(&prob_mem, &b.beta, grid[k]);
        }
        let warm = po.points.iter().filter(|s| s.warm_started).count();
        assert!(warm >= grid.len() - 1, "{method:?}: warm {warm}");
    }
}

/// Coordinator e2e on a `.saifbin` dataset registered by path: every
/// response is certified, and the served betas are bitwise identical
/// to serving the same requests from the in-memory design.
#[cfg(not(miri))]
#[test]
fn coordinator_serves_saifbin_bitwise_like_in_memory() {
    let mut rng = Rng::new(7200);
    let ds = random_dataset(&mut rng, false, false);
    let spilled = spill(&ds, "coord");
    let path = spilled.path.as_deref().unwrap();
    let prob_mem = std::sync::Arc::new(ds.problem());
    let lam_max = prob_mem.lambda_max();
    let fracs = [0.4f64, 0.2, 0.1];
    let spec = || SolveSpec {
        eps: 1e-9,
        pool: Some(common::test_pool_mode()),
        ..Default::default()
    };

    // out-of-core: registered by path, one handle per worker slot
    let mut c = Coordinator::builder().workers(2).build();
    c.register_saifbin(5, path).unwrap();
    for (i, f) in fracs.iter().enumerate() {
        c.submit_registered(i as u64, 5, lam_max * f, Method::Saif, spec()).unwrap();
    }
    let mut ooc_responses = c.drain().unwrap();
    c.shutdown();
    ooc_responses.sort_by_key(|r| r.id);

    // in-memory reference: same requests, inline problems
    let mut c = Coordinator::builder().workers(2).build();
    for (i, f) in fracs.iter().enumerate() {
        c.submit(saif::coordinator::SolveRequest {
            id: i as u64,
            dataset_key: 5,
            problem: prob_mem.clone(),
            lam: lam_max * f,
            method: Method::Saif,
            tree: None,
            warm: None,
            spec: spec(),
        })
        .unwrap();
    }
    let mut mem_responses = c.drain().unwrap();
    c.shutdown();
    mem_responses.sort_by_key(|r| r.id);

    assert_eq!(ooc_responses.len(), fracs.len());
    for (a, b) in ooc_responses.iter().zip(&mem_responses) {
        assert_eq!(a.beta, b.beta, "req {}: ooc β ≠ mem β", a.id);
        assert_eq!(a.kkt_violation.to_bits(), b.kkt_violation.to_bits());
        common::assert_kkt(&prob_mem, &a.beta, a.lam);
        assert!(a.gap <= 1e-9, "req {}: gap {}", a.id, a.gap);
    }
    let warm = ooc_responses.iter().filter(|r| r.warm_started).count();
    assert!(warm >= 2, "descending λ batch must warm-chain: {warm}");
}

/// Unknown keys and fused-on-out-of-core fail cleanly before anything
/// is queued; the coordinator stays usable afterwards.
#[cfg(not(miri))]
#[test]
fn submit_registered_rejections_are_clean_errors() {
    let mut c = Coordinator::builder().workers(1).build();
    let err = c
        .submit_registered(0, 99, 0.5, Method::Saif, SolveSpec::default())
        .unwrap_err();
    assert_eq!(err, CoordinatorError::UnknownDataset { key: 99 });
    // fused would densify the design per worker slot — rejected even
    // for a registered key, so check it against one that exists
    let mut rng = Rng::new(7400);
    let ds = random_dataset(&mut rng, false, false);
    let spilled = spill(&ds, "reject");
    c.register_saifbin(3, spilled.path.as_deref().unwrap()).unwrap();
    let err = c
        .submit_registered(1, 3, 0.5, Method::Fused, SolveSpec::default())
        .unwrap_err();
    assert_eq!(err, CoordinatorError::FusedOnOutOfCore { key: 3 });
    assert!(c.drain().unwrap().is_empty(), "nothing was queued");
    c.shutdown();
}

/// The rejection paths have no filesystem dependency at all — they run
/// under Miri against a byte-backed registration-free coordinator.
#[cfg(miri)]
#[test]
fn submit_unknown_dataset_is_a_clean_error() {
    let mut c = Coordinator::builder().workers(1).build();
    let err = c
        .submit_registered(0, 99, 0.5, Method::Saif, SolveSpec::default())
        .unwrap_err();
    assert_eq!(err, CoordinatorError::UnknownDataset { key: 99 });
    assert!(c.drain().unwrap().is_empty(), "nothing was queued");
    c.shutdown();
}

/// A tiny column cache (constant eviction) and a zero cache must not
/// change a single bit of a solve.
#[cfg(not(miri))]
#[test]
fn cache_pressure_does_not_change_solve_bits() {
    let mut rng = Rng::new(7300);
    let ds = random_dataset(&mut rng, false, false);
    let spilled = spill(&ds, "cache");
    let path = spilled.path.as_deref().unwrap();
    let lam = ds.problem().lambda_max() * 0.2;
    let solve = |x: Design| {
        let prob = Problem::new(x, ds.y.clone(), ds.loss);
        let mut eng = saif::cm::NativeEngine::new();
        let spec = SolveSpec { eps: 1e-9, ..Default::default() };
        make(Method::Saif, &mut eng, &spec).solve(&prob, lam).beta
    };
    let full = solve(spilled.ds.x.clone());
    for budget in [0usize, 256] {
        let starved = OocCsc::open_with_cache(path, budget).unwrap();
        assert_eq!(solve(Design::OocCsc(starved)), full, "budget={budget}");
    }
    assert_eq!(solve(ds.x.clone()), full, "ooc ≠ mem");
}
