//! Cross-validation of the two Engine backends: the PJRT-loaded
//! JAX/Pallas artifacts must agree with the native f64 implementation
//! (identical semantics, f32 tolerance). Skipped when the artifacts
//! have not been built (`make artifacts`).

use saif::cm::{Engine, NativeEngine};
use saif::data::synth;
use saif::model::LossKind;
use saif::runtime::{artifacts_available, PjrtEngine};
use saif::saif::{Saif, SaifConfig};

fn require_artifacts() -> Option<PjrtEngine> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::new().expect("PJRT engine"))
}

#[test]
fn cm_eval_agrees_ls() {
    let Some(mut pjrt) = require_artifacts() else { return };
    let mut native = NativeEngine::new();
    let ds = synth::synth_linear(60, 40, 101);
    let prob = ds.problem();
    let lam = prob.lambda_max() * 0.2;
    let active: Vec<usize> = (0..prob.p()).collect();
    let mut b1 = vec![0.0; prob.p()];
    let mut b2 = vec![0.0; prob.p()];
    let e1 = native.cm_eval(&prob, &active, &mut b1, lam, 10);
    let e2 = pjrt.cm_eval(&prob, &active, &mut b2, lam, 10);
    // f32 path vs f64 path: relative agreement
    let scale = e1.primal.abs().max(1.0);
    assert!(
        (e1.primal - e2.primal).abs() < 2e-4 * scale,
        "primal {} vs {}",
        e1.primal,
        e2.primal
    );
    assert!(
        (e1.dual - e2.dual).abs() < 2e-4 * scale,
        "dual {} vs {}",
        e1.dual,
        e2.dual
    );
    for i in 0..prob.p() {
        assert!(
            (b1[i] - b2[i]).abs() < 1e-3 * b1[i].abs().max(1.0),
            "beta[{i}]: {} vs {}",
            b1[i],
            b2[i]
        );
    }
}

#[test]
fn cm_eval_agrees_logistic() {
    let Some(mut pjrt) = require_artifacts() else { return };
    let mut native = NativeEngine::new();
    let ds = synth::gisette_like(80, 50, 103);
    let prob = ds.problem();
    assert_eq!(prob.loss, LossKind::Logistic);
    let lam = prob.lambda_max() * 0.3;
    let active: Vec<usize> = (0..prob.p()).collect();
    let mut b1 = vec![0.0; prob.p()];
    let mut b2 = vec![0.0; prob.p()];
    let e1 = native.cm_eval(&prob, &active, &mut b1, lam, 10);
    let e2 = pjrt.cm_eval(&prob, &active, &mut b2, lam, 10);
    let scale = e1.primal.abs().max(1.0);
    assert!((e1.primal - e2.primal).abs() < 5e-4 * scale);
    assert!((e1.gap - e2.gap).abs() < 5e-3 * scale, "gap {} vs {}", e1.gap, e2.gap);
    for i in 0..prob.p() {
        assert!((b1[i] - b2[i]).abs() < 2e-3 * b1[i].abs().max(1.0));
    }
}

#[test]
fn scores_agree() {
    let Some(mut pjrt) = require_artifacts() else { return };
    let mut native = NativeEngine::new();
    let ds = synth::synth_linear(100, 3000, 105);
    let prob = ds.problem();
    let theta: Vec<f64> = (0..prob.n()).map(|j| (j as f64 * 0.37).sin() * 0.01).collect();
    let s1 = native.scores(&prob, &theta);
    let s2 = pjrt.scores(&prob, &theta);
    assert_eq!(s1.len(), s2.len());
    for i in 0..s1.len() {
        assert!(
            (s1[i] - s2[i]).abs() < 1e-3 * s1[i].abs().max(1.0),
            "scores[{i}]: {} vs {}",
            s1[i],
            s2[i]
        );
    }
}

#[test]
fn saif_end_to_end_on_pjrt_engine() {
    let Some(mut pjrt) = require_artifacts() else { return };
    let ds = synth::synth_linear(100, 2000, 107);
    let prob = ds.problem();
    let lam = prob.lambda_max() * 0.2;
    // f32 artifacts: use a gap achievable in f32 (relative to primal
    // scale, which is large on this unstandardized sim data)
    let eps = 1e-2;
    let mut s = Saif::new(&mut pjrt, SaifConfig { eps, ..Default::default() });
    let res = s.solve(&prob, lam);
    assert!(res.gap <= eps, "gap {}", res.gap);
    assert!(res.max_active < 1024, "bucket overflow {}", res.max_active);
    // support agrees with the exact native solve
    let mut native = NativeEngine::new();
    let mut s2 = Saif::new(&mut native, SaifConfig { eps: 1e-9, ..Default::default() });
    let exact = s2.solve(&prob, lam);
    let sup_pjrt: std::collections::HashSet<usize> =
        res.beta.iter().filter(|(_, b)| b.abs() > 1e-4).map(|&(i, _)| i).collect();
    let sup_exact: std::collections::HashSet<usize> =
        exact.beta.iter().filter(|(_, b)| b.abs() > 1e-4).map(|&(i, _)| i).collect();
    // f32 vs f64 at loose gap: supports need not be identical, but the
    // overlap must be overwhelming
    let inter = sup_pjrt.intersection(&sup_exact).count();
    assert!(
        inter * 10 >= sup_exact.len() * 8,
        "support overlap {inter}/{} too small",
        sup_exact.len()
    );
    // every returned coefficient close to the exact one
    let exact_map: std::collections::HashMap<usize, f64> = exact.beta.iter().cloned().collect();
    for &(i, b) in &res.beta {
        let e = exact_map.get(&i).copied().unwrap_or(0.0);
        assert!((b - e).abs() < 0.05 * e.abs().max(1.0), "β[{i}] {b} vs {e}");
    }
}
