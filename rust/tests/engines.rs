//! Cross-validation of the two Engine backends: the PJRT-loaded
//! JAX/Pallas artifacts must agree with the native f64 implementation
//! (identical semantics, f32 tolerance). Skipped when the artifacts
//! have not been built (`make artifacts`).

mod common;

use saif::cm::{Engine, NativeEngine};
use saif::data::synth;
use saif::model::LossKind;
use saif::runtime::{artifacts_available, PjrtEngine};
use saif::saif::{Saif, SaifConfig};

fn require_artifacts() -> Option<PjrtEngine> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::new().expect("PJRT engine"))
}

#[test]
fn sharded_native_engine_agrees_with_serial_native() {
    // same cross-validation contract as native-vs-PJRT, but between
    // the serial and the sharded configurations of the native engine
    // (f64 vs f64, so tolerances are tight); runs without artifacts
    use saif::cm::EpochShards;
    for ds in [synth::synth_linear(60, 400, 111), synth::gisette_like(60, 400, 112)] {
        let prob = ds.problem();
        let lam = prob.lambda_max() * 0.2;
        let eps = 1e-10;
        let mut serial = NativeEngine::new();
        let r1 = Saif::new(&mut serial, SaifConfig { eps, ..Default::default() })
            .solve(&prob, lam);
        let mut sharded = NativeEngine::new();
        sharded.set_epoch_shards(EpochShards::Fixed(4));
        let r2 = Saif::new(&mut sharded, SaifConfig { eps, ..Default::default() })
            .solve(&prob, lam);
        common::assert_certificate(&prob, &r1.beta, lam, r1.gap, eps);
        common::assert_certificate(&prob, &r2.beta, lam, r2.gap, eps);
        common::check_supports_match(&r1.beta, &r2.beta, common::SUPPORT_TOL, "serial vs sharded")
            .unwrap();
        // both primals sit within eps of the same optimum value
        let scale = r1.primal.abs().max(1.0);
        assert!(
            (r1.primal - r2.primal).abs() <= 2.0 * eps * scale,
            "primal {} vs {}",
            r1.primal,
            r2.primal
        );
    }
}

#[test]
fn cm_eval_agrees_ls() {
    let Some(mut pjrt) = require_artifacts() else { return };
    let mut native = NativeEngine::new();
    let ds = synth::synth_linear(60, 40, 101);
    let prob = ds.problem();
    let lam = prob.lambda_max() * 0.2;
    let active: Vec<usize> = (0..prob.p()).collect();
    let mut b1 = vec![0.0; prob.p()];
    let mut b2 = vec![0.0; prob.p()];
    let e1 = native.cm_eval(&prob, &active, &mut b1, lam, 10);
    let e2 = pjrt.cm_eval(&prob, &active, &mut b2, lam, 10);
    // f32 path vs f64 path: relative agreement
    let scale = e1.primal.abs().max(1.0);
    assert!(
        (e1.primal - e2.primal).abs() < 2e-4 * scale,
        "primal {} vs {}",
        e1.primal,
        e2.primal
    );
    assert!(
        (e1.dual - e2.dual).abs() < 2e-4 * scale,
        "dual {} vs {}",
        e1.dual,
        e2.dual
    );
    for i in 0..prob.p() {
        assert!(
            (b1[i] - b2[i]).abs() < 1e-3 * b1[i].abs().max(1.0),
            "beta[{i}]: {} vs {}",
            b1[i],
            b2[i]
        );
    }
}

#[test]
fn cm_eval_agrees_logistic() {
    let Some(mut pjrt) = require_artifacts() else { return };
    let mut native = NativeEngine::new();
    let ds = synth::gisette_like(80, 50, 103);
    let prob = ds.problem();
    assert_eq!(prob.loss, LossKind::Logistic);
    let lam = prob.lambda_max() * 0.3;
    let active: Vec<usize> = (0..prob.p()).collect();
    let mut b1 = vec![0.0; prob.p()];
    let mut b2 = vec![0.0; prob.p()];
    let e1 = native.cm_eval(&prob, &active, &mut b1, lam, 10);
    let e2 = pjrt.cm_eval(&prob, &active, &mut b2, lam, 10);
    let scale = e1.primal.abs().max(1.0);
    assert!((e1.primal - e2.primal).abs() < 5e-4 * scale);
    assert!((e1.gap - e2.gap).abs() < 5e-3 * scale, "gap {} vs {}", e1.gap, e2.gap);
    for i in 0..prob.p() {
        assert!((b1[i] - b2[i]).abs() < 2e-3 * b1[i].abs().max(1.0));
    }
}

#[test]
fn scores_agree() {
    let Some(mut pjrt) = require_artifacts() else { return };
    let mut native = NativeEngine::new();
    let ds = synth::synth_linear(100, 3000, 105);
    let prob = ds.problem();
    let theta: Vec<f64> = (0..prob.n()).map(|j| (j as f64 * 0.37).sin() * 0.01).collect();
    let s1 = native.scores(&prob, &theta);
    let s2 = pjrt.scores(&prob, &theta);
    assert_eq!(s1.len(), s2.len());
    for i in 0..s1.len() {
        assert!(
            (s1[i] - s2[i]).abs() < 1e-3 * s1[i].abs().max(1.0),
            "scores[{i}]: {} vs {}",
            s1[i],
            s2[i]
        );
    }
}

#[test]
fn saif_end_to_end_on_pjrt_engine() {
    let Some(mut pjrt) = require_artifacts() else { return };
    let ds = synth::synth_linear(100, 2000, 107);
    let prob = ds.problem();
    let lam = prob.lambda_max() * 0.2;
    // f32 artifacts: use a gap achievable in f32 (relative to primal
    // scale, which is large on this unstandardized sim data)
    let eps = 1e-2;
    let mut s = Saif::new(&mut pjrt, SaifConfig { eps, ..Default::default() });
    let res = s.solve(&prob, lam);
    common::check_gap(res.gap, eps).unwrap();
    assert!(res.max_active < 1024, "bucket overflow {}", res.max_active);
    // support agrees with the exact native solve (which also carries
    // the full f64 certificate)
    let mut native = NativeEngine::new();
    let mut s2 = Saif::new(&mut native, SaifConfig { eps: 1e-9, ..Default::default() });
    let exact = s2.solve(&prob, lam);
    common::assert_certificate(&prob, &exact.beta, lam, exact.gap, 1e-9);
    let sup_pjrt: std::collections::HashSet<usize> =
        common::support_sparse(&res.beta, 1e-4).into_iter().collect();
    let sup_exact: std::collections::HashSet<usize> =
        common::support_sparse(&exact.beta, 1e-4).into_iter().collect();
    // f32 vs f64 at loose gap: supports need not be identical, but the
    // overlap must be overwhelming
    let inter = sup_pjrt.intersection(&sup_exact).count();
    assert!(
        inter * 10 >= sup_exact.len() * 8,
        "support overlap {inter}/{} too small",
        sup_exact.len()
    );
    // every returned coefficient close to the exact one
    let exact_map: std::collections::HashMap<usize, f64> = exact.beta.iter().cloned().collect();
    for &(i, b) in &res.beta {
        let e = exact_map.get(&i).copied().unwrap_or(0.0);
        assert!((b - e).abs() < 0.05 * e.abs().max(1.0), "β[{i}] {b} vs {e}");
    }
}
