//! Cross-module integration: all solvers agree on solutions across
//! datasets/losses; experiments run end-to-end at smoke scale; CSV
//! outputs land where the harness expects them.

use saif::cm::NativeEngine;
use saif::data::{self, synth};
use saif::homotopy::{Homotopy, HomotopyConfig};
use saif::saif::{Saif, SaifConfig};
use saif::screening::dpp::DppPath;
use saif::screening::dynamic::{DynScreen, DynScreenConfig};
use saif::workingset::{Blitz, BlitzConfig};

fn support(beta: &[(usize, f64)]) -> Vec<usize> {
    let mut s: Vec<usize> = beta
        .iter()
        .filter(|(_, b)| b.abs() > 1e-7)
        .map(|&(i, _)| i)
        .collect();
    s.sort();
    s
}

#[test]
fn all_safe_methods_agree_ls() {
    let prob = synth::synth_linear(50, 400, 7777).problem();
    let lam = prob.lambda_max() * 0.08;
    let eps = 1e-9;

    let mut e1 = NativeEngine::new();
    let saif_res = Saif::new(&mut e1, SaifConfig { eps, ..Default::default() }).solve(&prob, lam);
    let mut e2 = NativeEngine::new();
    let dyn_res =
        DynScreen::new(&mut e2, DynScreenConfig { eps, ..Default::default() }).solve(&prob, lam);
    let mut e3 = NativeEngine::new();
    let blitz_res =
        Blitz::new(&mut e3, BlitzConfig { eps, ..Default::default() }).solve(&prob, lam);
    let mut e4 = NativeEngine::new();
    let (dpp_steps, _) = DppPath::new(&mut e4, eps).solve_path(&prob, &[lam]).unwrap();

    let s = support(&saif_res.beta);
    assert_eq!(s, support(&dyn_res.beta), "saif vs dynamic");
    assert_eq!(s, support(&blitz_res.beta), "saif vs blitz");
    assert_eq!(s, support(&dpp_steps[0].beta), "saif vs dpp");
}

#[test]
fn all_safe_methods_agree_logistic() {
    let prob = synth::usps_like(120, 64, 7778).problem();
    let lam = prob.lambda_max() * 0.1;
    let eps = 1e-9;
    let mut e1 = NativeEngine::new();
    let saif_res = Saif::new(&mut e1, SaifConfig { eps, ..Default::default() }).solve(&prob, lam);
    let mut e2 = NativeEngine::new();
    let dyn_res =
        DynScreen::new(&mut e2, DynScreenConfig { eps, ..Default::default() }).solve(&prob, lam);
    assert_eq!(support(&saif_res.beta), support(&dyn_res.beta));
}

#[test]
fn homotopy_runs_the_full_registry_of_datasets() {
    // every registry dataset must be loadable and solvable at mid-λ
    for name in ["sim-small", "bc-small", "usps", "pet"] {
        let ds = data::by_name(name, 5).unwrap();
        // keep runtime sane: subsample big logistic sets
        if ds.n() > 600 {
            continue;
        }
        let prob = ds.problem();
        let lam = prob.lambda_max() * 0.3;
        let mut eng = NativeEngine::new();
        let mut saif = Saif::new(&mut eng, SaifConfig::default());
        let res = saif.solve(&prob, lam);
        assert!(res.gap <= 1e-6, "{name}: gap {}", res.gap);
    }
}

#[test]
fn homotopy_path_vs_saif_recall_below_or_equal_one() {
    let prob = synth::synth_linear(60, 300, 7779).problem();
    let lam_max = prob.lambda_max();
    let lams: Vec<f64> = (1..=15)
        .map(|k| lam_max * (1e-2f64).powf(k as f64 / 15.0))
        .collect();
    let mut eng = NativeEngine::new();
    let mut hom = Homotopy::new(&mut eng, HomotopyConfig::default());
    let (steps, _) = hom.solve_path(&prob, &lams);
    assert_eq!(steps.len(), lams.len());
    // homotopy's support is sane: within 2x of the exact size at the end
    let mut e2 = NativeEngine::new();
    let mut saif = Saif::new(&mut e2, SaifConfig { eps: 1e-9, ..Default::default() });
    let exact = saif.solve(&prob, *lams.last().unwrap());
    let exact_n = exact.beta.len().max(1);
    let hom_n = steps.last().unwrap().beta.len();
    assert!(hom_n <= exact_n * 2 && hom_n + exact_n >= exact_n, "{hom_n} vs {exact_n}");
}

#[test]
fn experiment_smoke_complexity_and_ablation() {
    // the cheapest experiments run end-to-end and write CSV
    let out = std::env::temp_dir().join("saif_exp_smoke");
    let out = out.to_str().unwrap();
    let tables = saif::experiments::run("abl-ball", out).expect("abl-ball");
    assert!(!tables.is_empty());
    assert!(!tables[0].rows.is_empty());
    let found = std::fs::read_dir(out)
        .unwrap()
        .filter_map(|e| e.ok())
        .any(|e| e.file_name().to_string_lossy().starts_with("abl-ball"));
    assert!(found, "CSV not written");
    std::fs::remove_dir_all(out).ok();
}

#[test]
fn libsvm_cli_path_round_trips_through_solver() {
    let ds = synth::synth_linear(30, 60, 11);
    let path = std::env::temp_dir().join("saif_int_io.svm");
    let path_s = path.to_str().unwrap();
    data::io::write_libsvm(&ds, path_s).unwrap();
    let back = data::io::read_libsvm(path_s, false).unwrap();
    let prob = back.problem();
    let lam = prob.lambda_max() * 0.2;
    let mut eng = NativeEngine::new();
    let res = Saif::new(&mut eng, SaifConfig::default()).solve(&prob, lam);
    assert!(res.gap <= 1e-6);
    std::fs::remove_file(path).ok();
}
