//! Mixed-precision (f32 scan) safety suite. The claim under test is
//! exactly the one `linalg/mixed.rs` makes: running recruitment over
//! the packed f32 shadow changes WHICH columns get scanned in (it may
//! over-recruit), but never the safety of the result — the certified
//! rounding bound means the mixed screen can only discard a feature
//! the f64 screen also discards, and everything downstream (CM
//! epochs, gaps, KKT certificates) is f64 under either setting. The
//! suite checks the screening-set property directly, checks end-to-end
//! solves against the f64 reference across backends and losses, and
//! fault-injects an under-sized bound to prove the failure mode is a
//! loud f64 KKT-oracle miss, not a silently wrong certificate.

mod common;

use saif::cm::NativeEngine;
use saif::data::synth;
use saif::linalg::{Design, MixedShadow, Precision};
use saif::model::Problem;
use saif::saif::{Saif, SaifConfig};
use saif::util::prop;

/// The set a screen with threshold `tau` discards: columns whose score
/// fails the ball test. (Screening keeps big scores; discards small.)
fn screened_out(scores: &[f64], tau: f64) -> Vec<usize> {
    (0..scores.len()).filter(|&j| scores[j] < tau).collect()
}

#[test]
fn mixed_screen_discards_a_subset_of_the_f64_screen() {
    prop::check("mixed ⊆ f64 screen", 10, |rng| {
        let n = 20 + rng.below(60);
        let p = 30 + rng.below(120);
        let ds = if rng.uniform() > 0.5 {
            synth::synth_linear(n, p, rng.next_u64())
        } else {
            synth::synth_sparse(n, p, 0.1, rng.next_u64())
        };
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let shadow = MixedShadow::build(&ds.x);
        let upper = shadow.scores_upper(&v);
        let mut truth = vec![0.0; p];
        ds.x.mul_t_vec(&v, &mut truth);
        for j in 0..p {
            if upper[j] < truth[j].abs() {
                return Err(format!(
                    "col {j}: mixed score {} below true |x_jᵀv| = {}",
                    upper[j],
                    truth[j].abs()
                ));
            }
        }
        // the set property the elementwise bound buys, stated as the
        // screen sees it: at EVERY threshold, a column the mixed scan
        // discards is also discarded by the f64 scan
        let abs_truth: Vec<f64> = truth.iter().map(|t| t.abs()).collect();
        for _ in 0..6 {
            let tau = abs_truth[rng.below(p)] * (0.5 + rng.uniform());
            let mixed_out = screened_out(&upper, tau);
            let f64_out = screened_out(&abs_truth, tau);
            for j in &mixed_out {
                if !f64_out.contains(j) {
                    return Err(format!(
                        "τ={tau:.3e}: mixed discarded col {j} that the f64 screen keeps"
                    ));
                }
            }
        }
        Ok(())
    });
}

fn solve_with(prob: &Problem, lam: f64, precision: Precision) -> saif::saif::SaifResult {
    let mut eng = NativeEngine::new();
    let mut s = Saif::new(
        &mut eng,
        SaifConfig { eps: 1e-9, precision, ..Default::default() },
    );
    s.solve(prob, lam)
}

/// End-to-end across backends and losses: the mixed-precision solve
/// must land on the same support as the f64 solve and certify through
/// the same full-problem f64 KKT oracle — precision is not allowed to
/// leak into anything a caller can observe except runtime.
#[test]
fn mixed_solve_matches_f64_solve_and_certifies() {
    prop::check("mixed solve == f64 solve", 8, |rng| {
        let n = 30 + rng.below(40);
        let p = 60 + rng.below(160);
        let which = rng.below(3);
        let prob = match which {
            0 => synth::synth_linear(n, p, rng.next_u64()).problem(),
            1 => synth::synth_sparse(n, p, 0.08, rng.next_u64()).problem(),
            _ => synth::gisette_like(n, p, rng.next_u64()).problem(),
        };
        let lam = prob.lambda_max() * (0.05 + 0.3 * rng.uniform());
        let f64_res = solve_with(&prob, lam, Precision::F64);
        let mixed_res = solve_with(&prob, lam, Precision::MixedF32);
        common::check_gap(f64_res.gap, 1e-9)?;
        common::check_gap(mixed_res.gap, 1e-9)?;
        // the logistic oracle tolerance matches safety.rs
        let tol = if which == 2 { 1e-2 } else { common::KKT_REL_TOL };
        common::check_kkt(&prob, &f64_res.beta, lam, tol)?;
        common::check_kkt(&prob, &mixed_res.beta, lam, tol)?;
        common::check_supports_match(
            &mixed_res.beta,
            &f64_res.beta,
            1e-8,
            "mixed vs f64 precision",
        )?;
        Ok(())
    });
}

/// The out-of-core backend packs its shadow through a different code
/// path (a streamed one-pass read); a mixed solve over it must certify
/// and agree with the in-memory mixed solve.
#[test]
fn mixed_solve_certifies_on_the_out_of_core_backend() {
    let ds = synth::synth_sparse(50, 250, 0.08, 7331);
    let bytes = saif::data::io::saifbin_bytes(&ds);
    let mut ooc_ds = ds.clone();
    ooc_ds.x =
        Design::OocCsc(saif::linalg::OocCsc::from_bytes(bytes).expect("parse saifbin bytes"));
    let (prob, ooc_prob) = (ds.problem(), ooc_ds.problem());
    let lam = prob.lambda_max() * 0.08;
    let mem = solve_with(&prob, lam, Precision::MixedF32);
    let ooc = solve_with(&ooc_prob, lam, Precision::MixedF32);
    common::assert_certificate(&ooc_prob, &ooc.beta, lam, ooc.gap, 1e-9);
    common::check_supports_match(&ooc.beta, &mem.beta, 1e-8, "ooc vs in-memory mixed")
        .unwrap_or_else(|e| panic!("{e}"));
}

/// Fault injection: flip the rounding bound's sign and blow it up, so
/// every mixed score is hugely UNDER-estimated and recruitment never
/// fires — the solver SafeStops on its initial active set. The point
/// of the test: that failure surfaces as a full-problem f64 KKT-oracle
/// miss, not as a certified-looking result. (With the honest bound the
/// identical configuration certifies — checked first, so this test
/// cannot pass vacuously.)
#[test]
fn under_sized_bound_is_caught_by_the_kkt_oracle_not_certified() {
    // small c ⇒ small initial top-h seed, so suppressed recruitment
    // genuinely strands the solve short of the true support
    let base = SaifConfig {
        eps: 1e-9,
        c: 0.1,
        precision: Precision::MixedF32,
        ..Default::default()
    };
    let mut any_caught = false;
    for seed in [4242, 90210, 31337] {
        let prob = synth::synth_linear(40, 200, seed).problem();
        let lam = prob.lambda_max() * 0.03;
        let mut eng = NativeEngine::new();
        let mut honest = Saif::new(&mut eng, base.clone());
        let res = honest.solve(&prob, lam);
        common::assert_certificate(&prob, &res.beta, lam, res.gap, 1e-9);

        let mut eng2 = NativeEngine::new();
        let mut sabotaged = Saif::new(
            &mut eng2,
            SaifConfig { mixed_bound_scale: -1e9, ..base.clone() },
        );
        let bad = sabotaged.solve(&prob, lam);
        if common::check_kkt(&prob, &bad.beta, lam, common::KKT_REL_TOL).is_err() {
            any_caught = true;
        }
    }
    assert!(
        any_caught,
        "sabotaged rounding bound was never caught by the f64 KKT oracle — \
         the oracle is not actually checking the full problem"
    );
}
