//! Pooled-vs-scoped parity: the persistent worker pool must be a pure
//! substrate swap. For a fixed chunk/shard count the pooled scans and
//! sharded epochs produce the SAME BITS as the spawn-per-call scoped
//! dispatch (and as serial for shards=1), on dense and sparse designs,
//! least-squares and logistic losses — plus the panic-isolation
//! regression: a crashing task surfaces as an error, not a hang, and
//! the pool stays usable.

mod common;

use saif::cm::{solve_subproblem, Engine, EpochShards, NativeEngine, PoolMode, SubEval};
use saif::data::synth;
use saif::linalg::Parallelism;
use saif::model::{LossKind, Problem};
use saif::runtime::pool::{self, PoolError, WorkerPool};
use saif::util::prop;
use saif::util::Rng;

/// Random problem drawn over {dense, sparse} × {ls, logistic}, wide
/// enough (p ≥ 64) that Fixed(4) genuinely runs 4 shards.
fn random_problem(rng: &mut Rng) -> Problem {
    let n = 20 + rng.below(40);
    let p = 64 + rng.below(120);
    let sparse = rng.uniform() > 0.5;
    let logistic = rng.uniform() > 0.5;
    let ds = if sparse {
        synth::synth_sparse(n, p, 0.05 + 0.15 * rng.uniform(), rng.next_u64())
    } else {
        synth::synth_linear(n, p, rng.next_u64())
    };
    if logistic {
        let y: Vec<f64> =
            ds.y.iter().map(|v| if *v >= 0.0 { 1.0 } else { -1.0 }).collect();
        Problem::new(ds.x, y, LossKind::Logistic)
    } else {
        ds.problem()
    }
}

fn solve_with(eng: &mut NativeEngine, prob: &Problem, lam: f64, eps: f64) -> (Vec<f64>, SubEval) {
    let active: Vec<usize> = (0..prob.p()).collect();
    let mut beta = vec![0.0; prob.p()];
    let (eval, _) = solve_subproblem(eng, prob, &active, &mut beta, lam, eps, 10, 400_000);
    (beta, eval)
}

fn sparse_beta(beta: &[f64]) -> Vec<(usize, f64)> {
    beta.iter().enumerate().filter(|(_, b)| **b != 0.0).map(|(i, &b)| (i, b)).collect()
}

#[test]
fn pooled_vs_scoped_parity_randomized() {
    prop::check("pooled == scoped dispatch", 8, |rng| {
        let prob = random_problem(rng);
        let lam = prob.lambda_max() * (0.05 + 0.3 * rng.uniform());
        let eps = 1e-11;

        let mut serial = NativeEngine::new();
        let (b_ser, ev_ser) = solve_with(&mut serial, &prob, lam, eps);

        for shards in [1usize, 2, 4] {
            let run = |mode: PoolMode| {
                let mut eng = NativeEngine::new();
                eng.set_epoch_shards(EpochShards::Fixed(shards));
                eng.set_parallelism(Parallelism::Fixed(2));
                eng.set_pool_mode(mode);
                solve_with(&mut eng, &prob, lam, eps)
            };
            let (b_pool, ev_pool) = run(PoolMode::Persistent);
            let (b_scope, ev_scope) = run(PoolMode::Scoped);
            // the substrate swap changes NOTHING: bitwise for every
            // fixed shard count, on either loss and either backend
            if b_pool != b_scope {
                return Err(format!("shards={shards}: pooled β ≠ scoped β bitwise"));
            }
            if ev_pool.primal.to_bits() != ev_scope.primal.to_bits() {
                return Err(format!(
                    "shards={shards}: primal bits differ: {} vs {}",
                    ev_pool.primal, ev_scope.primal
                ));
            }
            if shards == 1 && b_pool != b_ser {
                return Err("shards=1 pooled β differs bitwise from serial".into());
            }
            // vs the serial reference: same objective + KKT oracle
            prop::assert_close(
                ev_pool.primal,
                ev_ser.primal,
                1e-10,
                1e-10,
                &format!("primal (shards={shards}, {:?})", prob.loss),
            )?;
            common::check_certificate(&prob, &sparse_beta(&b_pool), lam, ev_pool.gap, eps)
                .map_err(|e| format!("shards={shards}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn pooled_scores_scan_is_bitwise_scoped() {
    let mut rng = Rng::new(61);
    for prob in [
        synth::synth_linear(40, 800, 62).problem(),
        synth::synth_sparse(40, 1200, 0.05, 63).problem(),
    ] {
        let theta: Vec<f64> = (0..prob.n()).map(|_| rng.normal() * 1e-2).collect();
        let mut serial = NativeEngine::new();
        let base = serial.scores(&prob, &theta);
        for threads in [2usize, 3, 8] {
            let run = |mode: PoolMode| {
                let mut eng = NativeEngine::with_parallelism(Parallelism::Fixed(threads));
                eng.set_pool_mode(mode);
                eng.scores(&prob, &theta)
            };
            let pooled = run(PoolMode::Persistent);
            let scoped = run(PoolMode::Scoped);
            assert_eq!(pooled, scoped, "threads={threads}");
            assert_eq!(pooled, base, "threads={threads} vs serial");
        }
    }
}

#[test]
fn env_driven_pool_mode_solves_and_certifies() {
    // ci.sh runs the threaded suite under SAIF_TEST_POOL ∈
    // {persistent, scoped}; whichever substrate is selected, a full
    // sharded solve must certify and match the serial objective
    let mode = common::test_pool_mode();
    let par = common::test_parallelism();
    let prob = synth::synth_linear(50, 700, 64).problem();
    let lam = prob.lambda_max() * 0.1;
    let eps = 1e-10;
    let mut serial = NativeEngine::new();
    let (_, ev_ser) = solve_with(&mut serial, &prob, lam, eps);
    let mut eng = NativeEngine::with_parallelism(par);
    eng.set_pool_mode(mode);
    let (b, ev) = solve_with(&mut eng, &prob, lam, eps);
    common::check_certificate(&prob, &sparse_beta(&b), lam, ev.gap, eps).unwrap();
    let scale = ev_ser.primal.abs().max(1.0);
    assert!(
        (ev.primal - ev_ser.primal).abs() <= 2.0 * eps * scale,
        "mode {mode:?}: primal {} vs serial {}",
        ev.primal,
        ev_ser.primal
    );
}

#[test]
fn pool_panic_isolation_regression() {
    // a panicking shard task must surface as an error on the caller —
    // never hang the run, never kill the pool's threads
    let pool = WorkerPool::new(2);
    let before = pool.threads();
    let err = pool
        .run_ordered(8, |i| {
            if i == 5 {
                panic!("shard {i} died");
            }
            i * 3
        })
        .unwrap_err();
    assert!(matches!(err, PoolError::TaskPanicked { task: 5, .. }), "{err}");
    assert_eq!(pool.threads(), before, "a panic must not cost a worker thread");
    // immediately reusable, results still ordered
    assert_eq!(pool.run_ordered(3, |i| i + 7).unwrap(), vec![7, 8, 9]);

    // same contract through the shared pool + mode dispatcher
    let err = pool::run_ordered_mode(PoolMode::Persistent, 4, |i| {
        if i == 0 {
            panic!("first task died");
        }
        i
    })
    .unwrap_err();
    assert!(matches!(err, PoolError::TaskPanicked { task: 0, .. }));
    let ok = pool::run_ordered_mode(PoolMode::Persistent, 4, |i| i).unwrap();
    assert_eq!(ok, vec![0, 1, 2, 3]);
}

#[test]
fn engine_panic_during_pooled_epoch_propagates_cleanly() {
    // a poisoned problem (NaN column norms are fine; an out-of-range
    // active index is not) panics inside the shard pass; the engine
    // must propagate it to the caller like the scoped path did, and
    // the shared pool must stay usable afterwards
    let prob = synth::synth_linear(20, 100, 65).problem();
    let lam = prob.lambda_max() * 0.1;
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut eng = NativeEngine::new();
        eng.set_epoch_shards(EpochShards::Fixed(2));
        let bad_active: Vec<usize> = (64..164).collect(); // 100 cols: out of range
        let mut beta = vec![0.0; bad_active.len()];
        eng.cm_eval(&prob, &bad_active, &mut beta, lam, 1);
    }));
    assert!(result.is_err(), "out-of-range active set must panic");
    // the pool survived the propagated panic
    let ok = pool::shared().run_ordered(5, |i| i * i).unwrap();
    assert_eq!(ok, vec![0, 1, 4, 9, 16]);
}
