//! Cross-method guarantees for the screening family behind the
//! shootout harness:
//!
//! * the SAFETY property every safe rule shares — no safe method ever
//!   discards a feature that is active at the optimum, so all of them
//!   (SAIF, dynamic screening, DPP, GAP-safe sphere/dome ×
//!   static/dynamic, hybrid safe-strong) land on the no-screening
//!   reference support, across dense/sparse designs and both losses;
//! * objective parity: GAP-safe and hybrid match SAIF's primal
//!   objective to 1e-8 under the shared KKT oracle;
//! * the worked counterexample where the plain (unsafe) strong rule —
//!   and the homotopy baseline built on it — misses an active feature
//!   that the hybrid rule's KKT post-check catches, with the honest
//!   full-problem gap exposing the homotopy miss.

mod common;

use saif::cm::{solve_subproblem, NativeEngine};
use saif::data::synth;
use saif::linalg::Mat;
use saif::model::{LossKind, Problem};
use saif::screening::dpp::DppPath;
use saif::screening::strong::strong_rule_keep;
use saif::solver::{make, Method, SolveSpec, Solver};
use saif::util::prop;

/// Primal objective of a sparse β — the shared yardstick for parity
/// checks (two optima of the same problem must agree in objective even
/// when near-threshold supports wobble).
fn objective(prob: &Problem, beta: &[(usize, f64)], lam: f64) -> f64 {
    let u = prob.margins_sparse(beta);
    let l1: f64 = beta.iter().map(|(_, b)| b.abs()).sum();
    prob.primal_from_margins(&u, l1, lam)
}

/// No-screening reference: solve on the full feature set.
fn reference_support(prob: &Problem, lam: f64, eps: f64) -> Vec<usize> {
    let all: Vec<usize> = (0..prob.p()).collect();
    let mut beta = vec![0.0; prob.p()];
    let mut eng = NativeEngine::new();
    solve_subproblem(&mut eng, prob, &all, &mut beta, lam, eps, 10, 500_000);
    common::support_dense(&beta, common::SUPPORT_TOL)
}

/// Every safe rule in the factory, exercised through the same
/// `Solver` entry point the coordinator and CLI use.
const SAFE_METHODS: &[Method] = &[
    Method::Saif,
    Method::DynScreen,
    Method::GapSafe { dome: true, dynamic: true },
    Method::GapSafe { dome: false, dynamic: true },
    Method::GapSafe { dome: true, dynamic: false },
    Method::GapSafe { dome: false, dynamic: false },
    Method::Hybrid,
];

#[test]
fn every_safe_rule_keeps_the_reference_support() {
    prop::check("safe rules share the exact support", 8, |rng| {
        let n = 30 + rng.below(40);
        let p = 80 + rng.below(160);
        let sparse = rng.uniform() > 0.5;
        let logistic = rng.uniform() > 0.5;
        let prob = match (sparse, logistic) {
            (false, false) => synth::synth_linear(n, p, rng.next_u64()).problem(),
            (false, true) => synth::gisette_like(n, p, rng.next_u64()).problem(),
            (true, false) => synth::synth_sparse(n, p, 0.05, rng.next_u64()).problem(),
            (true, true) => {
                let mut ds = synth::synth_sparse(n, p, 0.05, rng.next_u64());
                for v in ds.y.iter_mut() {
                    *v = if *v >= 0.0 { 1.0 } else { -1.0 };
                }
                ds.loss = LossKind::Logistic;
                ds.problem()
            }
        };
        let lam = prob.lambda_max() * (0.05 + 0.3 * rng.uniform());
        let eps = 1e-9;
        let reference = reference_support(&prob, lam, eps);
        for &method in SAFE_METHODS {
            let spec = SolveSpec { eps, ..Default::default() };
            let mut eng = NativeEngine::new();
            let sol = make(method, &mut eng, &spec).solve(&prob, lam);
            let sup = common::support_sparse(&sol.beta, common::SUPPORT_TOL);
            if sup != reference {
                return Err(format!(
                    "{}: support {sup:?} differs from reference {reference:?} \
                     (λ={lam:.3e}, {}{})",
                    method.label(),
                    if sparse { "sparse/" } else { "dense/" },
                    if logistic { "logistic" } else { "ls" },
                ));
            }
            common::check_gap(sol.gap, eps)?;
            common::check_kkt(&prob, &sol.beta, lam, common::KKT_REL_TOL)
                .map_err(|e| format!("{}: {e}", method.label()))?;
        }
        // DPP rides the path API and its ball is LS-specific
        if prob.loss == LossKind::Squared {
            let mut eng = NativeEngine::new();
            let (steps, _) = DppPath::new(&mut eng, eps)
                .solve_path(&prob, &[lam])
                .map_err(|e| format!("dpp: {e}"))?;
            let sup = common::support_sparse(&steps[0].beta, common::SUPPORT_TOL);
            if sup != reference {
                return Err(format!(
                    "dpp: support {sup:?} differs from reference {reference:?}"
                ));
            }
            common::check_kkt(&prob, &steps[0].beta, lam, common::KKT_REL_TOL)
                .map_err(|e| format!("dpp: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn gapsafe_and_hybrid_match_saif_objective_to_1e_8() {
    let problems = [
        ("ls", synth::synth_linear(50, 300, 71).problem()),
        ("logistic", synth::gisette_like(60, 150, 72).problem()),
    ];
    for (name, prob) in &problems {
        let lam = prob.lambda_max() * 0.1;
        let eps = 1e-10;
        let spec = SolveSpec { eps, ..Default::default() };
        let mut eng = NativeEngine::new();
        let saif_sol = make(Method::Saif, &mut eng, &spec).solve(prob, lam);
        common::assert_certificate(prob, &saif_sol.beta, lam, saif_sol.gap, eps);
        let obj_ref = objective(prob, &saif_sol.beta, lam);
        for method in [
            Method::GapSafe { dome: true, dynamic: true },
            Method::GapSafe { dome: false, dynamic: true },
            Method::GapSafe { dome: true, dynamic: false },
            Method::GapSafe { dome: false, dynamic: false },
            Method::Hybrid,
        ] {
            let mut eng2 = NativeEngine::new();
            let sol = make(method, &mut eng2, &spec).solve(prob, lam);
            common::assert_certificate(prob, &sol.beta, lam, sol.gap, eps);
            let obj = objective(prob, &sol.beta, lam);
            assert!(
                (obj - obj_ref).abs() <= 1e-8 * obj_ref.abs().max(1.0),
                "{name}/{}: objective {obj} vs saif {obj_ref}",
                method.label()
            );
        }
    }
}

/// The engineered miss: a 3×3 least-squares problem where at λ = 0.7
/// feature 2 is active (|x₂ᵀθ̂(0.7)| ≈ 1.157 > 1) but the sequential
/// strong rule stepping 1.0 → 0.7 excludes it (threshold 2λ − λ_prev =
/// 0.4 against |x₂ᵀ(y − u*(1.0))| = 0.05). Construction: x₀, x₁ at
/// angle cos⁻¹(0.9); y mostly along x₀+x₁ so both hit λ_max = 1.2
/// together; x₂ built orthogonal-ish so its correlation is tiny at
/// λ = 1.0 but blows past 1 at λ = 0.7.
fn strong_rule_counterexample() -> Problem {
    let a = 0.9_f64;
    let s19 = (1.0 - a * a).sqrt();
    let sum_nrm = (2.0 * (1.0 - a)).sqrt();
    let m = [(1.0 - a) / sum_nrm, s19 / sum_nrm, 0.0];
    let x2 = [-(a * m[0]), -(a * m[1]), -s19];
    let slope = a * sum_nrm / (1.0 - a);
    let u3 = -(slope * 1.0 - 0.05) / s19;
    let y = vec![12.0 * (1.0 - a), 12.0 * s19, u3];
    let cols = [[1.0, 0.0, 0.0], [-a, s19, 0.0], x2];
    Problem::new(Mat::from_fn(3, 3, |i, j| cols[j][i]), y, LossKind::Squared)
}

#[test]
fn strong_rule_misses_an_active_feature_that_hybrid_catches() {
    let prob = strong_rule_counterexample();
    let lam_max = prob.lambda_max();
    assert!((lam_max - 1.2).abs() < 1e-9, "λ_max = {lam_max}");
    let (lam_prev, lam) = (1.0, 0.7);
    let eps = 1e-9;

    // 1. feature 2 IS active at λ = 0.7 (the reference solve says so)
    let reference = reference_support(&prob, lam, 1e-12);
    assert!(reference.contains(&2), "reference support {reference:?}");

    // 2. the strong rule stepping λ_prev = 1.0 → λ = 0.7 excludes it
    let spec = SolveSpec { eps, ..Default::default() };
    let mut eng = NativeEngine::new();
    let at_prev = make(Method::Saif, &mut eng, &spec).solve(&prob, lam_prev);
    let u_prev = prob.margins_sparse(&at_prev.beta);
    let keep = strong_rule_keep(&prob, &u_prev, lam, lam_prev);
    assert!(keep.contains(&0), "strong keep {keep:?}");
    assert!(!keep.contains(&2), "strong rule should miss feature 2: {keep:?}");

    // 3. the homotopy baseline (strong rule, no safe post-check) walks
    //    the same path and misses — its honest FULL-problem gap exposes
    //    the miss instead of certifying the crippled solution
    let mut eng2 = NativeEngine::new();
    let hom = make(Method::Homotopy, &mut eng2, &spec).path(&prob, &[lam_prev, lam]);
    let hom_sup = common::support_sparse(&hom.points[1].beta, common::SUPPORT_TOL);
    assert!(
        !hom_sup.contains(&2),
        "homotopy unexpectedly found feature 2: {hom_sup:?}"
    );
    assert!(
        hom.points[1].gap > 1e-3,
        "honest gap must expose the miss, got {}",
        hom.points[1].gap
    );

    // 4. the hybrid rule takes the same strong proposal but KKT-checks
    //    it against the full problem: the violation on feature 2
    //    (|x₂ᵀθ̂| ≈ 1.65 > 1) triggers a re-solve that recovers it —
    //    through the warm path session, so the strong reference pair is
    //    really (u*(1.0), 1.0), not the trivial λ_max fallback
    let mut eng3 = NativeEngine::new();
    let hyb = make(Method::Hybrid, &mut eng3, &spec).path(&prob, &[lam_prev, lam]);
    let sol = &hyb.points[1];
    assert!(sol.warm_started, "second path point must be warm");
    let hyb_sup = common::support_sparse(&sol.beta, common::SUPPORT_TOL);
    assert!(hyb_sup.contains(&2), "hybrid must recover feature 2: {hyb_sup:?}");
    common::assert_certificate(&prob, &sol.beta, lam, sol.gap, eps);
    let violations = sol
        .stats
        .iter()
        .find(|(k, _)| *k == "violations")
        .map(|&(_, v)| v)
        .unwrap_or(0.0);
    assert!(
        violations >= 1.0,
        "the catch must be visible in the stats: violations = {violations}"
    );
}
