//! Cross-method guarantees for the screening family behind the
//! shootout harness:
//!
//! * the SAFETY property every safe rule shares — no safe method ever
//!   discards a feature that is active at the optimum, so all of them
//!   (SAIF, dynamic screening, DPP, GAP-safe sphere/dome ×
//!   static/dynamic, hybrid safe-strong) land on the no-screening
//!   reference support, across dense/sparse designs and both losses;
//! * objective parity: GAP-safe and hybrid match SAIF's primal
//!   objective to 1e-8 under the shared KKT oracle;
//! * the worked counterexample where the plain (unsafe) strong rule —
//!   and the homotopy baseline built on it — misses an active feature
//!   that the hybrid rule's KKT post-check catches, with the honest
//!   full-problem gap exposing the homotopy miss;
//! * the loss × penalty surface: elastic-net LS must match the
//!   explicit hand-built [X; √l2·I] reduction (≤1e-10 objective,
//!   support equality, l2 = 0 bitwise-plain), and every safe rule
//!   keeps the no-screening reference support on the squared-hinge,
//!   Huber, and elastic-net rows, countersigned by the penalized KKT
//!   oracle.

mod common;

use saif::cm::{solve_subproblem, NativeEngine};
use saif::data::synth;
use saif::linalg::Mat;
use saif::model::{LossKind, Penalty, Problem};
use saif::screening::dpp::DppPath;
use saif::screening::strong::strong_rule_keep;
use saif::solver::{make, Method, SolveSpec, Solver};
use saif::util::prop;

/// Primal objective of a sparse β — the shared yardstick for parity
/// checks (two optima of the same problem must agree in objective even
/// when near-threshold supports wobble).
fn objective(prob: &Problem, beta: &[(usize, f64)], lam: f64) -> f64 {
    let u = prob.margins_sparse(beta);
    let l1: f64 = beta.iter().map(|(_, b)| b.abs()).sum();
    prob.primal_from_margins(&u, l1, lam)
}

/// No-screening reference: solve on the full feature set.
fn reference_support(prob: &Problem, lam: f64, eps: f64) -> Vec<usize> {
    let all: Vec<usize> = (0..prob.p()).collect();
    let mut beta = vec![0.0; prob.p()];
    let mut eng = NativeEngine::new();
    solve_subproblem(&mut eng, prob, &all, &mut beta, lam, eps, 10, 500_000);
    common::support_dense(&beta, common::SUPPORT_TOL)
}

/// Every safe rule in the factory, exercised through the same
/// `Solver` entry point the coordinator and CLI use.
const SAFE_METHODS: &[Method] = &[
    Method::Saif,
    Method::DynScreen,
    Method::GapSafe { dome: true, dynamic: true },
    Method::GapSafe { dome: false, dynamic: true },
    Method::GapSafe { dome: true, dynamic: false },
    Method::GapSafe { dome: false, dynamic: false },
    Method::Hybrid,
];

#[test]
fn every_safe_rule_keeps_the_reference_support() {
    prop::check("safe rules share the exact support", 8, |rng| {
        let n = 30 + rng.below(40);
        let p = 80 + rng.below(160);
        let sparse = rng.uniform() > 0.5;
        let logistic = rng.uniform() > 0.5;
        let prob = match (sparse, logistic) {
            (false, false) => synth::synth_linear(n, p, rng.next_u64()).problem(),
            (false, true) => synth::gisette_like(n, p, rng.next_u64()).problem(),
            (true, false) => synth::synth_sparse(n, p, 0.05, rng.next_u64()).problem(),
            (true, true) => {
                let mut ds = synth::synth_sparse(n, p, 0.05, rng.next_u64());
                for v in ds.y.iter_mut() {
                    *v = if *v >= 0.0 { 1.0 } else { -1.0 };
                }
                ds.loss = LossKind::Logistic;
                ds.problem()
            }
        };
        let lam = prob.lambda_max() * (0.05 + 0.3 * rng.uniform());
        let eps = 1e-9;
        let reference = reference_support(&prob, lam, eps);
        for &method in SAFE_METHODS {
            let spec = SolveSpec { eps, ..Default::default() };
            let mut eng = NativeEngine::new();
            let sol = make(method, &mut eng, &spec).solve(&prob, lam);
            let sup = common::support_sparse(&sol.beta, common::SUPPORT_TOL);
            if sup != reference {
                return Err(format!(
                    "{}: support {sup:?} differs from reference {reference:?} \
                     (λ={lam:.3e}, {}{})",
                    method.label(),
                    if sparse { "sparse/" } else { "dense/" },
                    if logistic { "logistic" } else { "ls" },
                ));
            }
            common::check_gap(sol.gap, eps)?;
            common::check_kkt(&prob, &sol.beta, lam, common::KKT_REL_TOL)
                .map_err(|e| format!("{}: {e}", method.label()))?;
        }
        // DPP rides the path API and its ball is LS-specific
        if prob.loss == LossKind::Squared {
            let mut eng = NativeEngine::new();
            let (steps, _) = DppPath::new(&mut eng, eps)
                .solve_path(&prob, &[lam])
                .map_err(|e| format!("dpp: {e}"))?;
            let sup = common::support_sparse(&steps[0].beta, common::SUPPORT_TOL);
            if sup != reference {
                return Err(format!(
                    "dpp: support {sup:?} differs from reference {reference:?}"
                ));
            }
            common::check_kkt(&prob, &steps[0].beta, lam, common::KKT_REL_TOL)
                .map_err(|e| format!("dpp: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn gapsafe_and_hybrid_match_saif_objective_to_1e_8() {
    let problems = [
        ("ls", synth::synth_linear(50, 300, 71).problem()),
        ("logistic", synth::gisette_like(60, 150, 72).problem()),
    ];
    for (name, prob) in &problems {
        let lam = prob.lambda_max() * 0.1;
        let eps = 1e-10;
        let spec = SolveSpec { eps, ..Default::default() };
        let mut eng = NativeEngine::new();
        let saif_sol = make(Method::Saif, &mut eng, &spec).solve(prob, lam);
        common::assert_certificate(prob, &saif_sol.beta, lam, saif_sol.gap, eps);
        let obj_ref = objective(prob, &saif_sol.beta, lam);
        for method in [
            Method::GapSafe { dome: true, dynamic: true },
            Method::GapSafe { dome: false, dynamic: true },
            Method::GapSafe { dome: true, dynamic: false },
            Method::GapSafe { dome: false, dynamic: false },
            Method::Hybrid,
        ] {
            let mut eng2 = NativeEngine::new();
            let sol = make(method, &mut eng2, &spec).solve(prob, lam);
            common::assert_certificate(prob, &sol.beta, lam, sol.gap, eps);
            let obj = objective(prob, &sol.beta, lam);
            assert!(
                (obj - obj_ref).abs() <= 1e-8 * obj_ref.abs().max(1.0),
                "{name}/{}: objective {obj} vs saif {obj_ref}",
                method.label()
            );
        }
    }
}

/// The engineered miss: a 3×3 least-squares problem where at λ = 0.7
/// feature 2 is active (|x₂ᵀθ̂(0.7)| ≈ 1.157 > 1) but the sequential
/// strong rule stepping 1.0 → 0.7 excludes it (threshold 2λ − λ_prev =
/// 0.4 against |x₂ᵀ(y − u*(1.0))| = 0.05). Construction: x₀, x₁ at
/// angle cos⁻¹(0.9); y mostly along x₀+x₁ so both hit λ_max = 1.2
/// together; x₂ built orthogonal-ish so its correlation is tiny at
/// λ = 1.0 but blows past 1 at λ = 0.7.
fn strong_rule_counterexample() -> Problem {
    let a = 0.9_f64;
    let s19 = (1.0 - a * a).sqrt();
    let sum_nrm = (2.0 * (1.0 - a)).sqrt();
    let m = [(1.0 - a) / sum_nrm, s19 / sum_nrm, 0.0];
    let x2 = [-(a * m[0]), -(a * m[1]), -s19];
    let slope = a * sum_nrm / (1.0 - a);
    let u3 = -(slope * 1.0 - 0.05) / s19;
    let y = vec![12.0 * (1.0 - a), 12.0 * s19, u3];
    let cols = [[1.0, 0.0, 0.0], [-a, s19, 0.0], x2];
    Problem::new(Mat::from_fn(3, 3, |i, j| cols[j][i]), y, LossKind::Squared)
}

#[test]
fn strong_rule_misses_an_active_feature_that_hybrid_catches() {
    let prob = strong_rule_counterexample();
    let lam_max = prob.lambda_max();
    assert!((lam_max - 1.2).abs() < 1e-9, "λ_max = {lam_max}");
    let (lam_prev, lam) = (1.0, 0.7);
    let eps = 1e-9;

    // 1. feature 2 IS active at λ = 0.7 (the reference solve says so)
    let reference = reference_support(&prob, lam, 1e-12);
    assert!(reference.contains(&2), "reference support {reference:?}");

    // 2. the strong rule stepping λ_prev = 1.0 → λ = 0.7 excludes it
    let spec = SolveSpec { eps, ..Default::default() };
    let mut eng = NativeEngine::new();
    let at_prev = make(Method::Saif, &mut eng, &spec).solve(&prob, lam_prev);
    let u_prev = prob.margins_sparse(&at_prev.beta);
    let keep = strong_rule_keep(&prob, &u_prev, lam, lam_prev);
    assert!(keep.contains(&0), "strong keep {keep:?}");
    assert!(!keep.contains(&2), "strong rule should miss feature 2: {keep:?}");

    // 3. the homotopy baseline (strong rule, no safe post-check) walks
    //    the same path and misses — its honest FULL-problem gap exposes
    //    the miss instead of certifying the crippled solution
    let mut eng2 = NativeEngine::new();
    let hom = make(Method::Homotopy, &mut eng2, &spec).path(&prob, &[lam_prev, lam]);
    let hom_sup = common::support_sparse(&hom.points[1].beta, common::SUPPORT_TOL);
    assert!(
        !hom_sup.contains(&2),
        "homotopy unexpectedly found feature 2: {hom_sup:?}"
    );
    assert!(
        hom.points[1].gap > 1e-3,
        "honest gap must expose the miss, got {}",
        hom.points[1].gap
    );

    // 4. the hybrid rule takes the same strong proposal but KKT-checks
    //    it against the full problem: the violation on feature 2
    //    (|x₂ᵀθ̂| ≈ 1.65 > 1) triggers a re-solve that recovers it —
    //    through the warm path session, so the strong reference pair is
    //    really (u*(1.0), 1.0), not the trivial λ_max fallback
    let mut eng3 = NativeEngine::new();
    let hyb = make(Method::Hybrid, &mut eng3, &spec).path(&prob, &[lam_prev, lam]);
    let sol = &hyb.points[1];
    assert!(sol.warm_started, "second path point must be warm");
    let hyb_sup = common::support_sparse(&sol.beta, common::SUPPORT_TOL);
    assert!(hyb_sup.contains(&2), "hybrid must recover feature 2: {hyb_sup:?}");
    common::assert_certificate(&prob, &sol.beta, lam, sol.gap, eps);
    let violations = sol
        .stats
        .iter()
        .find(|(k, _)| *k == "violations")
        .map(|&(_, v)| v)
        .unwrap_or(0.0);
    assert!(
        violations >= 1.0,
        "the catch must be visible in the stats: violations = {violations}"
    );
}

/// The explicit rescaled-LASSO construction the elastic-net adapter is
/// specified against: design [X; √l2·I], response ỹ = [y; 0], plain ℓ1
/// at the same λ. Materialized dense — the test yardstick, not the
/// production path (which never builds the identity block).
fn augmented(prob: &Problem, l2: f64) -> Problem {
    let (n, p) = (prob.n(), prob.p());
    let mut xa = Mat::zeros(n + p, p);
    for j in 0..p {
        for (i, v) in prob.x.col_iter(j) {
            xa.set(i, j, v);
        }
        xa.set(n + j, j, l2.sqrt());
    }
    let mut y = prob.y.clone();
    y.resize(n + p, 0.0);
    Problem::new(xa, y, LossKind::Squared)
}

/// Elastic-net primal ½‖y−Xβ‖² + λ‖β‖₁ + ½·l2·‖β‖² — the objective
/// both sides of the reduction must agree on.
fn enet_objective(prob: &Problem, beta: &[(usize, f64)], lam: f64, l2: f64) -> f64 {
    let sq: f64 = beta.iter().map(|&(_, b)| b * b).sum();
    objective(prob, beta, lam) + 0.5 * l2 * sq
}

#[test]
fn elastic_net_matches_the_explicit_augmented_construction() {
    for (l2, seed) in [(0.1, 91u64), (0.75, 92)] {
        let prob = synth::synth_linear(40, 120, seed).problem();
        let pen = Penalty::ridge(l2);
        let lam = prob.lambda_max() * 0.15;
        let eps = 1e-12;
        // the API path: plain problem + SolveSpec penalty
        let spec = SolveSpec { eps, penalty: pen, ..Default::default() };
        let mut eng = NativeEngine::new();
        let sol = make(Method::Saif, &mut eng, &spec).solve(&prob, lam);
        // the hand-built reduction, solved as today's pure LASSO
        let aug = augmented(&prob, l2);
        let mut eng2 = NativeEngine::new();
        let plain = SolveSpec { eps, ..Default::default() };
        let ref_sol = make(Method::Saif, &mut eng2, &plain).solve(&aug, lam);
        let sup = common::support_sparse(&sol.beta, common::SUPPORT_TOL);
        let ref_sup = common::support_sparse(&ref_sol.beta, common::SUPPORT_TOL);
        assert_eq!(sup, ref_sup, "l2={l2}: support mismatch");
        let obj = enet_objective(&prob, &sol.beta, lam, l2);
        let obj_ref = enet_objective(&prob, &ref_sol.beta, lam, l2);
        assert!(
            (obj - obj_ref).abs() <= 1e-10 * obj_ref.abs().max(1.0),
            "l2={l2}: objective {obj} vs hand-rescaled {obj_ref}"
        );
        // both sides certify on the elastic-net KKT system
        let kkt = prob.kkt_violation_with(&sol.beta, lam, pen);
        assert!(kkt <= 1e-4 * lam.max(1.0), "l2={l2}: kkt {kkt}");
    }
    // l2 = 0 through the same adapter is bitwise today's LASSO
    let prob = synth::synth_linear(40, 120, 93).problem();
    let lam = prob.lambda_max() * 0.15;
    let zero = SolveSpec { penalty: Penalty { l1: 1.0, l2: 0.0 }, ..Default::default() };
    let plain = SolveSpec::default();
    let mut ea = NativeEngine::new();
    let mut eb = NativeEngine::new();
    let a = make(Method::Saif, &mut ea, &zero).solve(&prob, lam);
    let b = make(Method::Saif, &mut eb, &plain).solve(&prob, lam);
    assert_eq!(a.beta, b.beta, "l2=0 must be bitwise identical to plain LASSO");
    assert_eq!(a.gap.to_bits(), b.gap.to_bits());
}

#[test]
fn new_loss_penalty_surfaces_keep_the_reference_support() {
    prop::check("loss×penalty safe-rule supports", 6, |rng| {
        let n = 30 + rng.below(30);
        let p = 60 + rng.below(100);
        // rotate through the new surfaces: squared hinge, Huber, and
        // elastic-net least squares
        let (tag, prob, penalty) = match rng.below(3) {
            0 => {
                let mut ds = synth::gisette_like(n, p, rng.next_u64());
                ds.loss = LossKind::SquaredHinge;
                ("sqhinge", ds.problem(), Penalty::default())
            }
            1 => {
                let mut ds = synth::synth_linear(n, p, rng.next_u64());
                ds.loss = LossKind::Huber { delta: 0.5 + rng.uniform() };
                ("huber", ds.problem(), Penalty::default())
            }
            _ => {
                let ds = synth::synth_linear(n, p, rng.next_u64());
                ("enet-ls", ds.problem(), Penalty::ridge(0.05 + 0.3 * rng.uniform()))
            }
        };
        let lam = prob.lambda_max() * (0.05 + 0.3 * rng.uniform());
        let eps = 1e-9;
        // no-screening reference on the SAME surface — for the enet row
        // that is the explicit augmented problem, so the reduction
        // itself is part of what the reference countersigns
        let reference = if penalty.l2 > 0.0 {
            reference_support(&augmented(&prob, penalty.l2), lam, eps)
        } else {
            reference_support(&prob, lam, eps)
        };
        for &method in SAFE_METHODS {
            let spec = SolveSpec { eps, penalty, ..Default::default() };
            let mut eng = NativeEngine::new();
            let sol = make(method, &mut eng, &spec).solve(&prob, lam);
            let sup = common::support_sparse(&sol.beta, common::SUPPORT_TOL);
            if sup != reference {
                return Err(format!(
                    "{}/{tag}: support {sup:?} differs from reference {reference:?} (λ={lam:.3e})",
                    method.label(),
                ));
            }
            common::check_gap(sol.gap, eps)?;
            // KKT oracle countersigned on the full penalized problem
            let kkt = prob.kkt_violation_with(&sol.beta, lam, penalty);
            if kkt > common::KKT_REL_TOL * lam.max(1.0) {
                return Err(format!(
                    "{}/{tag}: kkt violation {kkt:.3e} at λ={lam:.3e}",
                    method.label(),
                ));
            }
        }
        Ok(())
    });
}
