//! End-to-end coordinator tests, including the full three-layer stack
//! (coordinator → PJRT runtime → AOT JAX/Pallas artifacts) when the
//! artifacts are built.

use std::sync::Arc;

use saif::coordinator::{Coordinator, EngineKind, Method, SolveRequest, SolveSpec};
use saif::data::synth;
use saif::runtime::artifacts_available;

fn path_requests(seed: u64, key: u64, n_lams: usize, eps: f64) -> Vec<SolveRequest> {
    let ds = synth::synth_linear(100, 900, seed);
    let prob = Arc::new(ds.problem());
    let lam_max = prob.lambda_max();
    (1..=n_lams)
        .map(|k| SolveRequest {
            id: key * 1000 + k as u64,
            dataset_key: key,
            problem: prob.clone(),
            lam: lam_max * (5e-2f64).powf(k as f64 / n_lams as f64),
            method: Method::Saif,
            tree: None,
            warm: None,
            spec: SolveSpec { eps, ..Default::default() },
        })
        .collect()
}

#[test]
fn multi_tenant_batch_native() {
    let mut reqs = Vec::new();
    for d in 0..3 {
        reqs.extend(path_requests(100 + d, d, 4, 1e-8));
    }
    let total = reqs.len();
    let batch = Coordinator::builder()
        .workers(3)
        .engine(EngineKind::Native)
        .run_batch(reqs)
        .expect("workers alive");
    assert_eq!(batch.responses.len(), total);
    assert!(batch.wall_secs > 0.0);
    assert_eq!(batch.latency.count(), total);
    for r in &batch.responses {
        assert!(r.gap <= 1e-8, "req {}: gap {}", r.id, r.gap);
        assert!(
            r.kkt_violation < 1e-3 * r.lam.max(1.0),
            "req {}: kkt {}",
            r.id,
            r.kkt_violation
        );
    }
}

#[test]
fn full_stack_pjrt_end_to_end() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut reqs = Vec::new();
    for d in 0..2 {
        // f32 artifacts: relative gap floor, use loose eps
        reqs.extend(path_requests(200 + d, d, 3, 1e-2));
    }
    let total = reqs.len();
    let batch = Coordinator::builder()
        .workers(2)
        .engine(EngineKind::Pjrt)
        .run_batch(reqs)
        .expect("workers alive");
    assert_eq!(batch.responses.len(), total);
    for r in &batch.responses {
        // coordinator certifies in f64 regardless of engine; f32 path
        // solutions are near-optimal: relative KKT violation small
        assert!(
            r.kkt_violation < 5e-2 * r.lam.max(1.0),
            "req {}: kkt {} (λ={})",
            r.id,
            r.kkt_violation,
            r.lam
        );
    }
}

#[test]
fn responses_preserve_request_ids() {
    let reqs = path_requests(300, 9, 5, 1e-6);
    let ids: std::collections::HashSet<u64> = reqs.iter().map(|r| r.id).collect();
    let batch = Coordinator::builder().workers(2).run_batch(reqs).expect("workers alive");
    let got: std::collections::HashSet<u64> = batch.responses.iter().map(|r| r.id).collect();
    assert_eq!(ids, got);
}
