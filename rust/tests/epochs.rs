//! Sharded active-block CM epochs: parity with the serial epoch,
//! certified by the shared KKT oracle (`tests/common`).
//!
//! The contract under test (see `cm::native`):
//! * shards = 1 is BITWISE identical to the serial epoch — same β,
//!   same primal bits, at every evaluation;
//! * shards > 1 changes the iterate trajectory (Jacobi across shards)
//!   but not the answer: the converged objective matches the serial
//!   solve within 1e-10 and the solution passes the KKT certificate —
//!   on dense and sparse designs, least-squares and logistic losses;
//! * a fixed shard count reproduces the same bits run-to-run (the
//!   ordered residual merge is deterministic).

mod common;

use saif::cm::{solve_subproblem, Engine, EpochShards, NativeEngine, SubEval};
use saif::data::synth;
use saif::linalg::Parallelism;
use saif::model::{LossKind, Problem};
use saif::saif::{Saif, SaifConfig};
use saif::util::prop;
use saif::util::Rng;

/// Random problem drawn over {dense, sparse} × {ls, logistic}.
/// p ≥ 64 so an explicit Fixed(4) policy genuinely runs 4 shards
/// (each shard must keep `NativeEngine::MIN_SHARD_COLS` = 16 columns).
fn random_problem(rng: &mut Rng) -> Problem {
    let n = 20 + rng.below(40);
    let p = 64 + rng.below(120);
    let sparse = rng.uniform() > 0.5;
    let logistic = rng.uniform() > 0.5;
    let ds = if sparse {
        synth::synth_sparse(n, p, 0.05 + 0.15 * rng.uniform(), rng.next_u64())
    } else {
        synth::synth_linear(n, p, rng.next_u64())
    };
    if logistic {
        // ±1 labels from the regression targets: a sparse/dense
        // logistic problem on the same design
        let y: Vec<f64> =
            ds.y.iter().map(|v| if *v >= 0.0 { 1.0 } else { -1.0 }).collect();
        Problem::new(ds.x, y, LossKind::Logistic)
    } else {
        ds.problem()
    }
}

/// Solve the reduced problem over ALL columns with the given engine.
fn solve_with(eng: &mut NativeEngine, prob: &Problem, lam: f64, eps: f64) -> (Vec<f64>, SubEval) {
    let active: Vec<usize> = (0..prob.p()).collect();
    let mut beta = vec![0.0; prob.p()];
    let (eval, _) = solve_subproblem(eng, prob, &active, &mut beta, lam, eps, 10, 400_000);
    (beta, eval)
}

fn sparse_beta(beta: &[f64]) -> Vec<(usize, f64)> {
    beta.iter().enumerate().filter(|(_, b)| **b != 0.0).map(|(i, &b)| (i, b)).collect()
}

#[test]
fn sharded_epoch_parity_randomized() {
    prop::check("sharded == serial epochs", 8, |rng| {
        let prob = random_problem(rng);
        let lam = prob.lambda_max() * (0.05 + 0.3 * rng.uniform());
        let eps = 1e-11;

        let mut serial = NativeEngine::new();
        let (b_ser, ev_ser) = solve_with(&mut serial, &prob, lam, eps);
        common::check_certificate(&prob, &sparse_beta(&b_ser), lam, ev_ser.gap, eps)?;

        // shards = 1: bitwise identical to the serial epoch
        let mut one = NativeEngine::new();
        one.set_epoch_shards(EpochShards::Fixed(1));
        let (b_one, ev_one) = solve_with(&mut one, &prob, lam, eps);
        if b_one != b_ser {
            return Err("shards=1 β differs bitwise from serial".into());
        }
        if ev_one.primal.to_bits() != ev_ser.primal.to_bits() {
            return Err(format!(
                "shards=1 primal bits differ: {} vs {}",
                ev_one.primal, ev_ser.primal
            ));
        }

        // shards ∈ {2, 4}: same objective within 1e-10 + KKT oracle
        for shards in [2usize, 4] {
            let mut eng = NativeEngine::new();
            eng.set_epoch_shards(EpochShards::Fixed(shards));
            let (b_sh, ev_sh) = solve_with(&mut eng, &prob, lam, eps);
            prop::assert_close(
                ev_sh.primal,
                ev_ser.primal,
                1e-10,
                1e-10,
                &format!("primal (shards={shards}, {:?})", prob.loss),
            )?;
            common::check_certificate(&prob, &sparse_beta(&b_sh), lam, ev_sh.gap, eps)
                .map_err(|e| format!("shards={shards}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn sharded_saif_end_to_end_randomized() {
    // the full SAIF loop (ADD/DEL + sharded reduced solves) stays safe
    prop::check("saif with sharded epochs is safe", 6, |rng| {
        let prob = random_problem(rng);
        let lam = prob.lambda_max() * (0.05 + 0.25 * rng.uniform());
        let eps = 1e-9;
        let mut serial = NativeEngine::new();
        let r_ser = Saif::new(&mut serial, SaifConfig { eps, ..Default::default() })
            .solve(&prob, lam);
        let shards = 2 + rng.below(3); // 2..=4
        let mut eng = NativeEngine::new();
        eng.set_epoch_shards(EpochShards::Fixed(shards));
        let r_sh =
            Saif::new(&mut eng, SaifConfig { eps, ..Default::default() }).solve(&prob, lam);
        common::check_certificate(&prob, &r_sh.beta, lam, r_sh.gap, eps)
            .map_err(|e| format!("shards={shards}: {e}"))?;
        common::check_supports_match(
            &r_ser.beta,
            &r_sh.beta,
            common::SUPPORT_TOL,
            "serial vs sharded SAIF",
        )
    });
}

#[test]
fn fixed_shard_count_reproduces_bitwise() {
    let prob = synth::synth_sparse(50, 500, 0.05, 77).problem();
    let lam = prob.lambda_max() * 0.1;
    let run = |shards: usize| {
        let mut eng = NativeEngine::new();
        eng.set_epoch_shards(EpochShards::Fixed(shards));
        let (beta, _) = solve_with(&mut eng, &prob, lam, 1e-10);
        beta
    };
    for shards in [2usize, 3, 4] {
        assert_eq!(run(shards), run(shards), "shards={shards} not reproducible");
    }
}

#[test]
fn env_driven_parallelism_exercises_epoch_path() {
    // ci.sh runs the suite with SAIF_TEST_THREADS ∈ {1, 4} and, for
    // the threaded runs, SAIF_TEST_POOL ∈ {persistent, scoped}: under
    // 4 threads the FollowParallelism engine shards this p=600 reduced
    // solve on the selected substrate, under 1 it stays serial — all
    // must certify and agree
    let par = common::test_parallelism();
    let prob = synth::synth_linear(50, 600, 88).problem();
    let lam = prob.lambda_max() * 0.1;
    let eps = 1e-10;
    let mut serial = NativeEngine::new();
    let (b_ser, ev_ser) = solve_with(&mut serial, &prob, lam, eps);
    let mut eng = NativeEngine::with_parallelism(par);
    eng.set_pool_mode(common::test_pool_mode());
    assert_eq!(
        eng.effective_epoch_shards(prob.p()),
        par.threads(prob.p()),
        "FollowParallelism must track the scan parallelism"
    );
    let (b_env, ev_env) = solve_with(&mut eng, &prob, lam, eps);
    common::check_certificate(&prob, &sparse_beta(&b_env), lam, ev_env.gap, eps).unwrap();
    let scale = ev_ser.primal.abs().max(1.0);
    assert!(
        (ev_env.primal - ev_ser.primal).abs() <= 2.0 * eps * scale,
        "primal {} vs {}",
        ev_env.primal,
        ev_ser.primal
    );
    if par.threads(prob.p()) <= 1 {
        // serial policy ⇒ the trajectory itself is identical
        assert_eq!(b_env, b_ser);
    }
}

#[test]
fn set_parallelism_late_matches_construction_time() {
    // regression (coordinator path): --threads applied AFTER engine
    // construction must shard epochs exactly like with_parallelism
    let prob = synth::synth_linear(40, 500, 99).problem();
    let lam = prob.lambda_max() * 0.15;
    let mut early = NativeEngine::with_parallelism(Parallelism::Fixed(3));
    let (b_early, _) = solve_with(&mut early, &prob, lam, 1e-10);
    let mut late = NativeEngine::new();
    late.set_parallelism(Parallelism::Fixed(3));
    assert_eq!(late.effective_epoch_shards(prob.p()), 3);
    let (b_late, _) = solve_with(&mut late, &prob, lam, 1e-10);
    assert_eq!(b_early, b_late, "late set_parallelism took a different epoch path");
}
