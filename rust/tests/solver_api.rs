//! Integration tests of the unified `Solver` API: λ-path parity with
//! independent solves, bitwise equivalence of the coordinator's path
//! batching with a manual SAIF warm chain, end-to-end serving of the
//! homotopy/fused/group adapters, dead-worker error surfacing, and the
//! standardized dense-vs-sparse (implicit centering) solve parity.

mod common;

use std::sync::Arc;

use saif::cm::NativeEngine;
use saif::coordinator::{Coordinator, CoordinatorError, SolveRequest};
use saif::data::{standardize, standardize_design, synth, Dataset};
use saif::linalg::CscMat;
use saif::model::{LossKind, Problem};
use saif::solver::{make, Method, SolveSpec, Solver};

fn objective(prob: &Problem, beta: &[(usize, f64)], lam: f64) -> f64 {
    let u = prob.margins_sparse(beta);
    let l1: f64 = beta.iter().map(|(_, b)| b.abs()).sum();
    prob.primal_from_margins(&u, l1, lam)
}

/// The dense/sparse × ls/logistic problem quartet.
fn parity_problems() -> Vec<(&'static str, Problem)> {
    let sparse_logistic = {
        let ds = synth::gisette_like(40, 70, 11);
        let sp = CscMat::from_dense(ds.x.as_dense());
        Problem::new(sp, ds.y, ds.loss)
    };
    vec![
        ("dense-ls", synth::synth_linear(40, 120, 21).problem()),
        ("sparse-ls", synth::synth_sparse(40, 200, 0.08, 23).problem()),
        ("dense-logistic", synth::gisette_like(40, 60, 25).problem()),
        ("sparse-logistic", sparse_logistic),
    ]
}

/// `path(&grid)` must match independent per-λ `solve` calls: identical
/// support and primal objective within 1e-10 (+ the two solves'
/// certified gaps — |P(β) − P(β')| ≤ gap + gap' always holds at a
/// shared optimum, so the bound is tight, not slack). BLITZ ignores
/// warm seeds, so for it the match is bitwise by construction; for
/// SAIF (warm-chained active sets) and dynamic screening (DPP-style
/// sequential-ball pre-screening on LS paths) it is the safe-screening
/// guarantee — a different trajectory converging to the same optimum.
#[test]
fn path_matches_independent_solves_for_safe_methods() {
    // 1e-11: tight enough that the gap terms keep the objective bound
    // at ~1e-10 scale, loose enough that BLITZ (no stall detector)
    // cannot spin on an f64 gap floor
    let eps = 1e-11;
    for (name, prob) in parity_problems() {
        let lam_max = prob.lambda_max();
        let grid: Vec<f64> = [0.5, 0.25, 0.12, 0.06].iter().map(|f| lam_max * f).collect();
        for method in [Method::Saif, Method::DynScreen, Method::Blitz] {
            let spec = SolveSpec { eps, ..Default::default() };
            let mut eng = NativeEngine::new();
            let path = make(method, &mut eng, &spec).path(&prob, &grid);
            assert_eq!(path.points.len(), grid.len());
            for (k, &lam) in grid.iter().enumerate() {
                let mut eng2 = NativeEngine::new();
                let solo = make(method, &mut eng2, &spec).solve(&prob, lam);
                let p_path = &path.points[k];
                common::check_supports_match(
                    &p_path.beta,
                    &solo.beta,
                    common::SUPPORT_TOL,
                    &format!("{name}/{:?} λ#{k}", method),
                )
                .unwrap();
                let (oa, ob) = (objective(&prob, &p_path.beta, lam), objective(&prob, &solo.beta, lam));
                let tol = 1e-10 * oa.abs().max(1.0) + p_path.gap + solo.gap;
                assert!(
                    (oa - ob).abs() <= tol,
                    "{name}/{:?} λ#{k}: path obj {oa} vs solo {ob} (tol {tol:e})",
                    method
                );
            }
        }
    }
}

/// Acceptance criterion: the coordinator's λ-descending batch for
/// `Method::Saif` is BITWISE identical to a manual `Solver::path` on
/// the same grid — the warm-start cache and path batching moved behind
/// `path()` without changing a single bit of the trajectory.
#[test]
fn coordinator_saif_batch_is_bitwise_a_path_session() {
    let ds = synth::synth_linear(60, 500, 31);
    let prob = Arc::new(ds.problem());
    let lam_max = prob.lambda_max();
    let grid: Vec<f64> = (1..=6).map(|k| lam_max * (3e-2f64).powf(k as f64 / 6.0)).collect();
    let spec = SolveSpec { eps: 1e-9, ..Default::default() };

    let mut eng = NativeEngine::new();
    let manual = make(Method::Saif, &mut eng, &spec).path(&prob, &grid);

    let reqs: Vec<SolveRequest> = grid
        .iter()
        .enumerate()
        .map(|(i, &lam)| SolveRequest {
            id: i as u64,
            dataset_key: 1,
            problem: prob.clone(),
            lam,
            method: Method::Saif,
            tree: None,
            warm: None,
            spec: spec.clone(),
        })
        .collect();
    let batch = Coordinator::builder().workers(1).run_batch(reqs).expect("workers alive");
    assert_eq!(batch.responses.len(), grid.len());
    let mut responses = batch.responses;
    responses.sort_by_key(|r| r.id);
    for (k, r) in responses.iter().enumerate() {
        assert_eq!(
            r.beta, manual.points[k].beta,
            "λ#{k}: coordinator β differs from path session"
        );
        assert_eq!(r.gap, manual.points[k].gap, "λ#{k}: gap differs");
        assert_eq!(r.warm_started, manual.points[k].warm_started);
    }
}

/// The homotopy adapter's `path()` runs the native sequential
/// strong-rule pass and reports the HONEST full-problem gap.
#[test]
fn homotopy_path_serves_and_reports_global_gap() {
    let ds = synth::synth_linear(50, 120, 33);
    let prob = ds.problem();
    let lam_max = prob.lambda_max();
    let grid: Vec<f64> = (1..=8).map(|k| lam_max * (0.8f64).powi(k)).collect();
    let spec = SolveSpec { eps: 1e-9, ..Default::default() };
    let mut eng = NativeEngine::new();
    let path = make(Method::Homotopy, &mut eng, &spec).path(&prob, &grid);
    assert_eq!(path.points.len(), grid.len());
    assert!(!path.points[0].warm_started);
    for (k, sol) in path.points.iter().enumerate() {
        assert!(sol.gap.is_finite() && sol.gap >= 0.0, "λ#{k}: gap {}", sol.gap);
        if k > 0 {
            assert!(sol.warm_started, "λ#{k} should chain the path state");
        }
    }
    // the unsafe method has no support-equality guarantee (Table 1);
    // assert the repo's recall precedent against the exact solve
    let mut eng2 = NativeEngine::new();
    let exact = make(Method::Saif, &mut eng2, &spec).solve(&prob, *grid.last().unwrap());
    let truth: Vec<usize> = common::support_sparse(&exact.beta, common::SUPPORT_TOL);
    let found: Vec<usize> =
        common::support_sparse(&path.points.last().unwrap().beta, common::SUPPORT_TOL);
    let (recall, _) = saif::homotopy::recall_precision(&found, &truth);
    assert!(recall > 0.6, "homotopy recall {recall}");
}

/// All six methods are servable: homotopy, fused (chain tree) and
/// group (contiguous blocks) requests flow through the coordinator and
/// come back with their method's own safety certificate.
#[test]
fn coordinator_serves_homotopy_fused_and_group() {
    let ds = synth::synth_linear(50, 80, 35);
    let prob = Arc::new(ds.problem());
    let lam_max = prob.lambda_max();
    let methods = [
        (Method::Homotopy, 1u64),
        (Method::Fused, 2u64),
        (Method::Group { size: 4 }, 3u64),
    ];
    let mut reqs = Vec::new();
    let mut id = 0;
    for &(method, key) in &methods {
        for f in [0.5, 0.35] {
            reqs.push(SolveRequest {
                id,
                dataset_key: key, // per-method keys: no cross-method warm reuse
                problem: prob.clone(),
                lam: lam_max * f,
                method,
                tree: None,
                warm: None,
                spec: SolveSpec { eps: 1e-9, ..Default::default() },
            });
            id += 1;
        }
    }
    let batch = Coordinator::builder().workers(2).run_batch(reqs).expect("workers alive");
    assert_eq!(batch.responses.len(), 6);
    for r in &batch.responses {
        assert!(r.gap.is_finite());
        assert!(
            r.kkt_violation < 1e-2 * r.lam.max(1.0),
            "req {} (dataset {}): certificate {:.3e} at λ={:.3e}",
            r.id,
            r.dataset_key,
            r.kkt_violation,
            r.lam
        );
    }
}

/// Served fused problems are no longer chain-tree-only: a request
/// carrying its dataset's real (non-chain) feature tree is solved over
/// that tree, and the coordinator's safety certificate is computed
/// against the SAME tree — cross-checked here with a direct
/// `fused_kkt_violation` call on the response.
#[test]
fn coordinator_serves_fused_with_dataset_tree() {
    use saif::fused::{fused_kkt_violation, FusedSaif};

    let ds = synth::gene_expr(40, 30, 55);
    let x = ds.x.as_dense().clone();
    let edges = saif::data::tree::preferential_attachment(30, 3);
    // not the chain 0−1−⋯−(p−1)
    assert!(edges.iter().any(|&(u, v)| v != u + 1 && u != v + 1));
    let lam_max =
        FusedSaif::lambda_max(&x, &ds.y, LossKind::Squared, &edges).expect("valid tree");
    let prob = Arc::new(ds.problem());
    let tree = Arc::new(edges.clone());
    let reqs: Vec<SolveRequest> = [0.5, 0.3]
        .iter()
        .enumerate()
        .map(|(i, f)| SolveRequest {
            id: i as u64,
            dataset_key: 9,
            problem: prob.clone(),
            lam: lam_max * f,
            method: Method::Fused,
            tree: Some(tree.clone()),
            warm: None,
            spec: SolveSpec { eps: 1e-9, ..Default::default() },
        })
        .collect();
    let batch = Coordinator::builder().workers(1).run_batch(reqs).expect("workers alive");
    assert_eq!(batch.responses.len(), 2);
    for r in &batch.responses {
        assert!(
            r.kkt_violation < 1e-2 * r.lam.max(1.0),
            "req {}: certificate {:.3e} at λ={:.3e}",
            r.id,
            r.kkt_violation,
            r.lam
        );
        // the response's certificate really is the non-chain tree's:
        // recomputing it directly against `edges` agrees
        let mut dense = vec![0.0; prob.p()];
        for &(i, b) in &r.beta {
            dense[i] = b;
        }
        let direct = fused_kkt_violation(&x, &ds.y, LossKind::Squared, &edges, &dense, r.lam)
            .expect("valid tree");
        assert!(
            (direct - r.kkt_violation).abs() <= 1e-9 * direct.abs().max(1.0),
            "req {}: coordinator certificate {} vs direct {}",
            r.id,
            r.kkt_violation,
            direct
        );
    }
}

/// A worker that dies (here: the group solver's LS-only assert tripped
/// by a logistic problem) surfaces as `CoordinatorError::WorkerDead`
/// with the worker's id — instead of the old `expect`-panic in the
/// caller.
#[test]
fn dead_worker_is_an_error_not_a_hang() {
    let ds = synth::gisette_like(30, 40, 37);
    let prob = Arc::new(ds.problem());
    let lam = prob.lambda_max() * 0.5;
    let mut c = Coordinator::builder().workers(1).build();
    c.submit(SolveRequest {
        id: 0,
        dataset_key: 0,
        problem: prob.clone(),
        lam,
        method: Method::Group { size: 4 }, // LS-only: panics on logistic
        tree: None,
        warm: None,
        spec: SolveSpec::default(),
    })
    .expect("first submit reaches the live worker");
    let err = c.drain().expect_err("drain must report the dead worker");
    assert_eq!(err, CoordinatorError::WorkerDead { worker: 0 });
    // the dead worker also rejects further submissions
    let err2 = c
        .submit(SolveRequest {
            id: 1,
            dataset_key: 0,
            problem: prob,
            lam,
            method: Method::Saif,
            tree: None,
            warm: None,
            spec: SolveSpec::default(),
        })
        .expect_err("submit to a dead worker must fail");
    assert_eq!(err2, CoordinatorError::WorkerDead { worker: 0 });
    c.shutdown();
}

/// Implicit centering end-to-end: a standardized sparse problem
/// (CSC + rank-1 mean correction) solves to the same support and
/// coefficients as the densely standardized copy.
#[test]
fn standardized_sparse_solve_matches_dense() {
    // sparse design with structurally nonzero column means
    let base = synth::synth_sparse(60, 300, 0.06, 41);
    let spm = match &base.x {
        saif::linalg::Design::Sparse(m) => m.clone(),
        _ => unreachable!("synth_sparse is CSC"),
    };
    let mut dense = spm.to_dense();
    let dstats = standardize(&mut dense);
    let mut sparse_design: saif::linalg::Design = spm.into();
    let sstats = standardize_design(&mut sparse_design);
    assert!(sparse_design.is_centered());
    for (d, s) in dstats.iter().zip(&sstats) {
        assert!((d.0 - s.0).abs() < 1e-12 && (d.1 - s.1).abs() < 1e-10);
    }

    let dense_ds = Dataset {
        name: "std-dense".into(),
        x: dense.into(),
        y: base.y.clone(),
        loss: LossKind::Squared,
        tree: None,
    };
    let sparse_ds = Dataset {
        name: "std-sparse".into(),
        x: sparse_design,
        y: base.y.clone(),
        loss: LossKind::Squared,
        tree: None,
    };
    let (dp, sp) = (dense_ds.problem(), sparse_ds.problem());
    assert!((dp.lambda_max() - sp.lambda_max()).abs() < 1e-9);

    let lam = dp.lambda_max() * 0.15;
    let spec = SolveSpec { eps: 1e-10, ..Default::default() };
    let mut e1 = NativeEngine::new();
    let a = make(Method::Saif, &mut e1, &spec).solve(&dp, lam);
    let mut e2 = NativeEngine::new();
    let b = make(Method::Saif, &mut e2, &spec).solve(&sp, lam);
    common::assert_certificate(&dp, &a.beta, lam, a.gap, 1e-10);
    common::assert_certificate(&sp, &b.beta, lam, b.gap, 1e-10);
    common::check_supports_match(&a.beta, &b.beta, common::SUPPORT_TOL, "std dense vs sparse")
        .unwrap();
    let mut bmap = vec![0.0; sp.p()];
    for &(i, v) in &b.beta {
        bmap[i] = v;
    }
    common::check_coeffs_match(&a.beta, &bmap, 1e-7, 1e-6).unwrap();
}

/// The per-request `SolveSpec` is honored through `path()`: a trace
/// request returns trace events, a loose ε stops earlier than a tight
/// one.
#[test]
fn spec_trace_and_eps_flow_through_path() {
    let prob = synth::synth_linear(40, 200, 43).problem();
    let lam = prob.lambda_max() * 0.2;
    let spec = SolveSpec { eps: 1e-8, trace: true, ..Default::default() };
    let mut eng = NativeEngine::new();
    let path = make(Method::Saif, &mut eng, &spec).path(&prob, &[lam, lam * 0.5]);
    for sol in &path.points {
        assert!(sol.gap <= 1e-8);
        assert!(!sol.trace.is_empty(), "trace requested but empty");
    }
}
