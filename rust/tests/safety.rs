//! The paper's central claim, tested adversarially: SAIF is SAFE —
//! it returns the optimum of the FULL problem (same support, same
//! coefficients, KKT-certified) no matter how the active set evolved.
//! Randomized across data distributions, losses, λ and hyper-params.

mod common;

use saif::cm::{solve_subproblem, NativeEngine};
use saif::data::synth;
use saif::model::{LossKind, Problem};
use saif::saif::{Saif, SaifConfig};
use saif::util::prop;

fn exact_support(prob: &Problem, lam: f64) -> (Vec<f64>, Vec<usize>) {
    let all: Vec<usize> = (0..prob.p()).collect();
    let mut beta = vec![0.0; prob.p()];
    let mut eng = NativeEngine::new();
    let (_e, _) =
        solve_subproblem(&mut eng, prob, &all, &mut beta, lam, 1e-10, 10, 500_000);
    let sup = common::support_dense(&beta, 1e-8);
    (beta, sup)
}

#[test]
fn saif_support_equals_exhaustive_support_randomized() {
    prop::check("saif == no-screening", 12, |rng| {
        let n = 20 + rng.below(60);
        let p = 50 + rng.below(250);
        let prob = if rng.uniform() > 0.4 {
            synth::synth_linear(n, p, rng.next_u64()).problem()
        } else {
            synth::gene_expr(n, p, rng.next_u64()).problem()
        };
        let lam = prob.lambda_max() * (0.01 + 0.4 * rng.uniform());
        let (full, sup) = exact_support(&prob, lam);
        let mut eng = NativeEngine::new();
        let cfg = SaifConfig {
            eps: 1e-10,
            c: 0.5 + 1.5 * rng.uniform(),
            zeta: 0.5 + 1.5 * rng.uniform(),
            use_thm2_ball: rng.uniform() > 0.5,
            ..Default::default()
        };
        let mut saif = Saif::new(&mut eng, cfg);
        let res = saif.solve(&prob, lam);
        let saif_sup = common::support_sparse(&res.beta, 1e-8);
        if saif_sup != sup {
            return Err(format!(
                "support mismatch: saif {saif_sup:?} vs exact {sup:?} (λ={lam:.3e})"
            ));
        }
        common::check_coeffs_match(&res.beta, &full, 1e-5, 1e-4)?;
        common::check_kkt(&prob, &res.beta, lam, common::KKT_REL_TOL)?;
        Ok(())
    });
}

#[test]
fn saif_logistic_safety_randomized() {
    prop::check("saif logistic safe", 8, |rng| {
        let n = 30 + rng.below(50);
        let p = 40 + rng.below(160);
        let prob = synth::gisette_like(n, p, rng.next_u64()).problem();
        let lam = prob.lambda_max() * (0.05 + 0.4 * rng.uniform());
        let mut eng = NativeEngine::new();
        let mut saif = Saif::new(
            &mut eng,
            SaifConfig { eps: 1e-9, ..Default::default() },
        );
        let res = saif.solve(&prob, lam);
        common::check_kkt(&prob, &res.beta, lam, 1e-2)?;
        Ok(())
    });
}

#[test]
fn saif_never_misses_active_feature_even_with_aggressive_delta() {
    // δ starting tiny screens aggressively early; safety must still
    // hold because the algorithm drives δ → 1 before the safe stop
    prop::check("delta schedule safe", 8, |rng| {
        let prob = synth::synth_linear(40, 200, rng.next_u64()).problem();
        let lam = prob.lambda_max() * 0.05;
        let (_, sup) = exact_support(&prob, lam);
        let mut eng = NativeEngine::new();
        let mut saif = Saif::new(
            &mut eng,
            SaifConfig { eps: 1e-10, delta0: Some(1e-6), ..Default::default() },
        );
        let res = saif.solve(&prob, lam);
        let got: std::collections::HashSet<usize> =
            res.beta.iter().map(|&(i, _)| i).collect();
        for i in &sup {
            if !got.contains(i) {
                return Err(format!("missed active feature {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn warm_start_from_wrong_solution_is_still_safe() {
    // adversarial warm start: seed SAIF with junk coefficients on
    // junk features — the result must still be the exact optimum
    prop::check("junk warm start", 6, |rng| {
        let prob = synth::synth_linear(40, 150, rng.next_u64()).problem();
        let lam = prob.lambda_max() * 0.1;
        let junk: Vec<(usize, f64)> = (0..20)
            .map(|_| (rng.below(prob.p()), rng.normal()))
            .collect();
        let mut eng = NativeEngine::new();
        let mut saif = Saif::new(
            &mut eng,
            SaifConfig { eps: 1e-10, ..Default::default() },
        );
        let res = saif.solve_warm(&prob, lam, Some(&junk));
        common::check_kkt(&prob, &res.beta, lam, common::KKT_REL_TOL)
            .map_err(|e| format!("junk warm start: {e}"))?;
        Ok(())
    });
}

#[test]
fn every_lambda_on_grid_is_safe() {
    let prob = synth::synth_linear(50, 300, 999).problem();
    let lam_max = prob.lambda_max();
    for k in 0..12 {
        let lam = lam_max * (1e-3f64).powf(k as f64 / 11.0);
        let mut eng = NativeEngine::new();
        let mut saif = Saif::new(
            &mut eng,
            SaifConfig { eps: 1e-9, ..Default::default() },
        );
        let res = saif.solve(&prob, lam);
        common::assert_certificate(&prob, &res.beta, lam, res.gap, 1e-9);
    }
}

#[test]
fn fused_saif_is_safe_on_trees() {
    use saif::fused::{FusedSaif, FusedSaifConfig};
    prop::check("fused safety", 6, |rng| {
        let p = 20 + rng.below(60);
        let n = 20 + rng.below(40);
        let ds = synth::gene_expr(n, p, rng.next_u64());
        let edges = saif::data::tree::preferential_attachment(p, rng.next_u64());
        let lam_max =
            FusedSaif::lambda_max(ds.x.as_dense(), &ds.y, LossKind::Squared, &edges).unwrap();
        let lam = lam_max * (0.05 + 0.5 * rng.uniform());
        let mut eng = NativeEngine::new();
        let mut fs = FusedSaif::new(
            &mut eng,
            FusedSaifConfig {
                saif: SaifConfig { eps: 1e-10, ..Default::default() },
                ..Default::default()
            },
        );
        let res = fs.solve(ds.x.as_dense(), &ds.y, LossKind::Squared, &edges, lam).unwrap();
        // certificate: ADMM from a different initialization cannot beat
        // SAIF's objective by more than the tolerance
        let mut admm = saif::fused::FusedAdmm::new(Default::default());
        let ares = admm.solve(ds.x.as_dense(), &ds.y, LossKind::Squared, &edges, lam, None);
        if ares.objective < res.objective - 1e-4 * res.objective.abs().max(1.0) {
            return Err(format!(
                "ADMM found better objective: {} < {}",
                ares.objective, res.objective
            ));
        }
        Ok(())
    });
}
