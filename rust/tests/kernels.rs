//! Kernel-contract suite for the unrolled/cache-blocked scan kernels
//! (docs/KERNELS.md): the blocked dense scan must be **bitwise
//! invariant** in the row-block height, the blocked `cols_axpy` fold
//! must be bitwise equal to the sequential fold it replaced, the
//! in-memory and out-of-core sparse backends must agree bit for bit
//! (they share `ops::gather_dot`), and the 8-wide `dot` must sit
//! within the analytic reordering bound of the plain sequential sum.
//! Problem sizes here deliberately exceed `ROW_BLOCK`/`COL_STRIP` so
//! multiple blocks and a partial strip are actually exercised.

mod common;

use saif::data::{synth, Dataset};
use saif::linalg::mat::{COL_STRIP, ROW_BLOCK};
use saif::linalg::ops::{self, UNROLL};
use saif::linalg::{Design, Mat, OocCsc};
use saif::util::Rng;

/// The pre-blocking scalar kernel: one left-to-right fold.
fn sequential_dot(x: &[f64], y: &[f64]) -> f64 {
    x.iter().zip(y).fold(0.0, |s, (a, b)| s + a * b)
}

#[test]
fn blocked_dense_scan_is_bitwise_invariant_in_block_size() {
    let mut rng = Rng::new(101);
    // > 2 row blocks at the smallest height below, plus a ragged tail;
    // > 1 column strip, plus a partial strip
    let (n, p) = (2 * ROW_BLOCK + 37, COL_STRIP + 5);
    let m = Mat::from_fn(n, p, |_, _| rng.normal());
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let want: Vec<f64> = (0..p).map(|j| ops::dot(m.col(j), &v)).collect();
    let mut default = vec![0.0; p];
    m.mul_t_vec(&v, &mut default);
    for j in 0..p {
        assert_eq!(default[j].to_bits(), want[j].to_bits(), "default rb, col {j}");
    }
    for rb in [UNROLL, 2 * UNROLL, 5 * UNROLL, ROW_BLOCK, 4 * ROW_BLOCK] {
        let mut got = vec![0.0; p];
        m.mul_t_vec_blocked(&v, &mut got, rb);
        for j in 0..p {
            assert_eq!(got[j].to_bits(), want[j].to_bits(), "rb={rb}, col {j}");
        }
    }
}

#[test]
fn pooled_blocked_scan_is_bitwise_serial_under_test_substrate() {
    // the CI matrix sets SAIF_TEST_THREADS / SAIF_TEST_POOL, so this
    // one assertion runs serial, pooled and scoped across the legs
    let mut rng = Rng::new(102);
    let (n, p) = (ROW_BLOCK + 11, 3 * COL_STRIP + 9);
    let m = Mat::from_fn(n, p, |_, _| rng.normal());
    let design = Design::Dense(m);
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut serial = vec![0.0; p];
    design.mul_t_vec(&v, &mut serial);
    let mut pooled = vec![0.0; p];
    design.mul_t_vec_pool(&v, &mut pooled, common::test_parallelism(), common::test_pool_mode());
    assert_eq!(serial, pooled);
}

#[test]
fn unrolled_dot_stays_within_the_reordering_bound_of_sequential() {
    // the 8-wide kernel reorders the same n products, so the two
    // results differ by at most the sum of both summation error
    // bounds: 2·γ_n·Σ|x_i·y_i| with γ_n ≈ n·u (Higham eq. 3.5); and
    // below one full unroll group the lane accumulators are all zero,
    // so the kernel degenerates to the sequential fold, bitwise
    let mut rng = Rng::new(103);
    for n in (0..40).chain([63, 64, 65, 1000, 4097]) {
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let got = ops::dot(&x, &y);
        let seq = sequential_dot(&x, &y);
        if n < UNROLL {
            assert_eq!(got.to_bits(), seq.to_bits(), "n={n} below one unroll group");
            continue;
        }
        let scale: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        let bound = 2.0 * (n as f64 + 8.0) * f64::EPSILON * scale;
        assert!(
            (got - seq).abs() <= bound,
            "n={n}: |{got} - {seq}| = {} > {bound}",
            (got - seq).abs()
        );
    }
}

#[test]
fn blocked_cols_axpy_is_bitwise_the_sequential_fold() {
    let mut rng = Rng::new(104);
    let (n, p) = (2 * ROW_BLOCK + 513, 24);
    let m = Mat::from_fn(n, p, |_, _| rng.normal());
    let design = Design::Dense(m);
    // repeats included: the ordered-fold contract says update k sees
    // the residual state left by updates 0..k, per element
    let updates: Vec<(usize, f64)> = (0..40).map(|_| (rng.below(p), rng.normal())).collect();
    let base: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut folded = base.clone();
    design.cols_axpy(&updates, &mut folded);
    let mut manual = base.clone();
    for &(j, a) in &updates {
        design.col_axpy(a, j, &mut manual);
    }
    for i in 0..n {
        assert_eq!(folded[i].to_bits(), manual[i].to_bits(), "row {i}");
    }
}

/// In-memory CSC and the out-of-core stream of the same `.saifbin`
/// bytes must agree **bitwise** on every kernel — both reduce through
/// `ops::gather_dot`, so this is equality by construction, pinned.
#[test]
fn ooc_backend_is_bitwise_identical_to_in_memory_csc() {
    let mut rng = Rng::new(105);
    let ds: Dataset = synth::synth_sparse(60, 400, 0.07, 9001);
    let bytes = saif::data::io::saifbin_bytes(&ds);
    let ooc = Design::OocCsc(OocCsc::from_bytes(bytes).expect("parse saifbin bytes"));
    let (n, p) = (ds.x.n_rows(), ds.x.n_cols());
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    for j in 0..p {
        assert_eq!(
            ds.x.col_dot(j, &v).to_bits(),
            ooc.col_dot(j, &v).to_bits(),
            "col_dot {j}"
        );
    }
    let (mut a, mut b) = (vec![0.0; p], vec![0.0; p]);
    ds.x.mul_t_vec(&v, &mut a);
    ooc.mul_t_vec(&v, &mut b);
    for j in 0..p {
        assert_eq!(a[j].to_bits(), b[j].to_bits(), "mul_t_vec {j}");
    }
    let norms_mem = ds.x.col_norms_sq();
    let norms_ooc = ooc.col_norms_sq();
    for j in 0..p {
        assert_eq!(norms_mem[j].to_bits(), norms_ooc[j].to_bits(), "col_norms_sq {j}");
    }
    let updates: Vec<(usize, f64)> = (0..16).map(|_| (rng.below(p), rng.normal())).collect();
    let (mut ra, mut rb) = (v.clone(), v.clone());
    ds.x.cols_axpy(&updates, &mut ra);
    ooc.cols_axpy(&updates, &mut rb);
    for i in 0..n {
        assert_eq!(ra[i].to_bits(), rb[i].to_bits(), "cols_axpy row {i}");
    }
}

#[test]
fn gather_dot_is_the_shared_sparse_reduction() {
    // gather_dot against an explicit densified column: same value to
    // within one reordering bound, and exact when products are exact
    let v: Vec<f64> = (0..32).map(|i| (i as f64) - 15.5).collect();
    let rows = [1usize, 4, 9, 16, 25, 31];
    let vals = [2.0, -1.0, 0.5, 4.0, -8.0, 1.0];
    let mut dense = vec![0.0; 32];
    for (&r, &a) in rows.iter().zip(&vals) {
        dense[r] = a;
    }
    // powers of two throughout: every product and partial sum is exact
    assert_eq!(ops::gather_dot(&rows, &vals, &v), sequential_dot(&dense, &v));
}
