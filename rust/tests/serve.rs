//! End-to-end tests for the TCP serving front-end (`saif::serve`):
//! real loopback sockets, real worker solves, and the invariants the
//! subsystem exists for —
//!
//! * every served β certifies on the FULL problem at the requested ε
//!   (including cache near-misses, which are warm-started and
//!   re-certified, never interpolated);
//! * exact cache hits are bitwise-identical to the solve that produced
//!   them, and a sequential served λ-grid is bitwise-identical to a
//!   direct [`Solver::path`] session;
//! * past the admission high-watermark requests get `Busy`, not a
//!   wedged connection;
//! * malformed frames draw typed errors and never take the server
//!   down;
//! * a worker slot poisoned mid-serve recovers without silently
//!   dropping accepted requests.

mod common;

use std::sync::Arc;
use std::time::Duration;

use saif::cm::{Engine, EpochShards, NativeEngine};
use saif::data::synth;
use saif::serve::client::Client;
use saif::serve::protocol::{code, CacheTag, Request, Response};
use saif::serve::{ServeConfig, ServeDataset, Server};
use saif::solver::{self, Method, SolveSpec, Solver};
use saif::util::json::Json;

const EPS: f64 = 1e-8;

/// Test-scoped serving config: engine knobs follow the CI matrix env
/// (SAIF_TEST_THREADS / SAIF_TEST_POOL), admission generous unless a
/// test overrides it.
fn test_config() -> ServeConfig {
    ServeConfig {
        workers: 1,
        max_conns: 8,
        high_watermark: 32,
        solve_timeout: Duration::from_secs(60),
        parallelism: common::test_parallelism(),
        epoch_shards: EpochShards::FollowParallelism,
        pool_mode: common::test_pool_mode(),
        ..ServeConfig::default()
    }
}

fn linear_dataset(key: u64, seed: u64) -> (ServeDataset, Arc<saif::model::Problem>) {
    let prob = Arc::new(synth::synth_linear(60, 300, seed).problem());
    (
        ServeDataset {
            key,
            name: format!("lin-{seed}"),
            problem: prob.clone(),
            tree: None,
        },
        prob,
    )
}

fn start(cfg: ServeConfig, datasets: Vec<ServeDataset>) -> Server {
    // several servers run concurrently in this binary, and every
    // accept loop / pump / blocked connection handler occupies a
    // shared-pool thread — grow the pool past the whole binary's
    // concurrent demand so no test can starve another's workers
    saif::runtime::pool::shared().ensure_threads(64);
    Server::start(cfg, datasets, "127.0.0.1:0").expect("bind loopback")
}

fn connect(server: &Server) -> Client {
    let mut c = Client::connect(server.local_addr()).expect("connect");
    c.set_read_timeout(Some(Duration::from_secs(120))).expect("read timeout");
    c
}

fn descending_grid(prob: &saif::model::Problem, k: usize) -> Vec<f64> {
    let lam_max = prob.lambda_max();
    (1..=k).map(|i| lam_max * (5e-2f64).powf(i as f64 / k as f64)).collect()
}

fn solved(rsp: Response) -> saif::serve::protocol::SolvedPoint {
    match rsp {
        Response::Solved(pt) => pt,
        other => panic!("expected Solved, got {other:?}"),
    }
}

fn beta_bits(beta: &[(usize, f64)]) -> Vec<(usize, u64)> {
    beta.iter().map(|&(i, b)| (i, b.to_bits())).collect()
}

#[test]
fn served_grid_is_bitwise_identical_to_direct_path_and_certified() {
    let (ds, prob) = linear_dataset(0, 7);
    let server = start(test_config(), vec![ds]);
    let lams = descending_grid(&prob, 5);

    // direct reference: ONE warm-chained path session on an engine
    // configured exactly like the server's worker
    let spec = SolveSpec { eps: EPS, ..Default::default() };
    let mut engine = NativeEngine::new();
    engine.set_parallelism(common::test_parallelism());
    engine.set_epoch_shards(EpochShards::FollowParallelism);
    engine.set_pool_mode(common::test_pool_mode());
    let direct = solver::make(Method::Saif, &mut engine, &spec).path(&prob, &lams);

    // served: a sequential client walking the same grid cold
    let mut client = connect(&server);
    let mut served = Vec::new();
    for &lam in &lams {
        let pt = solved(client.solve(0, lam, EPS, Method::Saif).expect("solve rpc"));
        // the serving invariant: FULL-problem certificate at the
        // requested ε, on every reply
        common::assert_certificate(&prob, &pt.beta, lam, pt.gap, EPS);
        served.push(pt);
    }
    for (pt, sol) in served.iter().zip(&direct.points) {
        assert_eq!(
            beta_bits(&pt.beta),
            beta_bits(&sol.beta),
            "served β must be bitwise-identical to the direct path session at λ={}",
            pt.lam
        );
        assert_eq!(pt.gap.to_bits(), sol.gap.to_bits(), "gap must match bitwise");
    }

    // exact cache hit: same (λ, ε) again is a bitwise replay
    let again = solved(client.solve(0, lams[2], EPS, Method::Saif).expect("repeat rpc"));
    assert_eq!(again.cache, CacheTag::Exact, "repeat of a served λ must hit the cache");
    assert_eq!(beta_bits(&again.beta), beta_bits(&served[2].beta));
    assert_eq!(again.gap.to_bits(), served[2].gap.to_bits());

    // near-miss: a λ between grid points is warm-started from the
    // nearest cached β and re-certified — never served uncertified
    let near_lam = lams[2] * 1.02;
    let near = solved(client.solve(0, near_lam, EPS, Method::Saif).expect("near rpc"));
    common::assert_certificate(&prob, &near.beta, near_lam, near.gap, EPS);

    // stats surface: the counters saw all of this
    let stats_json = match client.stats().expect("stats rpc") {
        Response::Stats(s) => s,
        other => panic!("expected Stats, got {other:?}"),
    };
    let j = Json::parse(&stats_json).expect("stats is valid JSON");
    let d0 = j.get("datasets").and_then(|d| d.get("0")).expect("dataset 0 in stats");
    let requests = d0.get("requests").and_then(|v| v.as_f64()).expect("requests counter");
    assert!(requests >= (lams.len() + 2) as f64, "requests={requests}");
    let exact = d0.get("exact_hits").and_then(|v| v.as_f64()).expect("exact_hits counter");
    assert!(exact >= 1.0, "exact_hits={exact}");
    drop(client);

    let final_stats = server.shutdown();
    assert!(final_stats.total(|d| d.exact_hits) >= 1);
    assert!(final_stats.connections >= 1);
}

#[test]
fn loss_and_penalty_surfaces_are_served_and_isolated() {
    use saif::model::{LossKind, Penalty};
    let (ds, prob) = linear_dataset(0, 29);
    let server = start(test_config(), vec![ds]);
    let lam = prob.lambda_max() * 0.2;
    let mut c = connect(&server);

    // elastic net end-to-end: the reply certifies on the PENALIZED
    // objective, and the β satisfies the elastic-net KKT conditions
    let pen = Penalty::ridge(0.25);
    let enet = solved(
        c.solve_on(0, lam, EPS, Method::Saif, LossKind::Squared, pen).expect("enet rpc"),
    );
    assert!(enet.gap <= EPS, "enet gap {} must certify the requested ε", enet.gap);
    let kkt = prob.kkt_violation_with(&enet.beta, lam, pen);
    assert!(kkt < 1e-4 * lam.max(1.0), "enet KKT residual {kkt}");

    // the plain-lasso request at the SAME λ must not be served from
    // the enet entry: its first solve is a cache miss and its β differs
    let plain = solved(c.solve(0, lam, EPS, Method::Saif).expect("plain rpc"));
    assert_eq!(plain.cache, CacheTag::Miss, "surfaces must never share cache entries");
    assert_ne!(
        beta_bits(&plain.beta),
        beta_bits(&enet.beta),
        "ridge shrinkage must be visible in the served β"
    );

    // a non-default loss end-to-end: served off a derived per-loss
    // problem, still with an honest full-problem certificate
    let hub = solved(
        c.solve_on(0, lam, EPS, Method::Saif, LossKind::Huber { delta: 1.0 }, Penalty::default())
            .expect("huber rpc"),
    );
    assert!(hub.gap <= EPS, "huber gap {} must certify the requested ε", hub.gap);

    // a classification loss on real-valued labels is a typed error
    match c
        .solve_on(0, lam, EPS, Method::Saif, LossKind::SquaredHinge, Penalty::default())
        .expect("rpc")
    {
        Response::Error { code: ec, .. } => assert_eq!(ec, code::BAD_REQUEST),
        other => panic!("expected BAD_REQUEST for ±1-label loss on real labels, got {other:?}"),
    }

    // structured methods reject the l2 penalty with a typed error
    match c.solve_on(0, lam, EPS, Method::Fused, LossKind::Squared, pen).expect("rpc") {
        Response::Error { code: ec, .. } => assert_eq!(ec, code::BAD_REQUEST),
        other => panic!("expected BAD_REQUEST for fused × l2, got {other:?}"),
    }
    drop(c);
    server.shutdown();
}

#[test]
fn watermark_zero_makes_every_cold_solve_busy() {
    let (ds, prob) = linear_dataset(0, 11);
    let cfg = ServeConfig { high_watermark: 0, retry_after_ms: 77, ..test_config() };
    let server = start(cfg, vec![ds]);
    let lam = prob.lambda_max() * 0.3;

    let mut client = connect(&server);
    match client.solve(0, lam, EPS, Method::Saif).expect("rpc") {
        Response::Busy { retry_after_ms } => assert_eq!(retry_after_ms, 77),
        other => panic!("expected Busy past the watermark, got {other:?}"),
    }
    // the connection is NOT wedged: the stats surface still answers
    match client.stats().expect("stats rpc") {
        Response::Stats(_) => {}
        other => panic!("expected Stats, got {other:?}"),
    }
    drop(client);
    let stats = server.shutdown();
    assert_eq!(stats.total(|d| d.rejected), 1, "the Busy must be counted as rejected");
}

#[test]
fn concurrent_hammer_terminates_with_busy_or_certified_answers() {
    let (ds, prob) = linear_dataset(0, 13);
    // tight watermark + concurrent clients: some get Busy, everyone
    // gets SOME answer (no deadlock, no dropped connection)
    let cfg = ServeConfig { high_watermark: 2, max_conns: 8, ..test_config() };
    let server = start(cfg, vec![ds]);
    let addr = server.local_addr();
    let lams = descending_grid(&prob, 4);

    let outcomes = saif::runtime::pool::scoped_run(6, |ci| {
        let mut client = Client::connect(addr).expect("connect");
        client.set_read_timeout(Some(Duration::from_secs(120))).expect("timeout");
        let (mut ok, mut busy) = (0usize, 0usize);
        for r in 0..4 {
            let lam = lams[(ci + r) % lams.len()];
            match client.solve(0, lam, EPS, Method::Saif).expect("rpc") {
                Response::Solved(pt) => {
                    common::assert_certificate(&prob, &pt.beta, lam, pt.gap, EPS);
                    ok += 1;
                }
                Response::Busy { .. } => busy += 1,
                other => panic!("client {ci}: unexpected {other:?}"),
            }
        }
        (ok, busy)
    })
    .expect("clients terminate");

    let total_ok: usize = outcomes.iter().map(|(ok, _)| ok).sum();
    assert!(total_ok >= 1, "at least some requests must be served under pressure");
    server.shutdown();
}

#[test]
fn malformed_frames_get_typed_errors_and_never_kill_the_server() {
    let (ds, prob) = linear_dataset(0, 17);
    let server = start(test_config(), vec![ds]);
    let lam = prob.lambda_max() * 0.3;

    // 1) garbage magic: typed BAD_FRAME error, connection closed
    let mut c = connect(&server);
    c.send_raw(&[0xde, 0xad, 0xbe, 0xef, 1, 0, 1, 0, 0, 0, 0, 0]).expect("send");
    match c.recv().expect("error reply") {
        Response::Error { code: ec, .. } => assert_eq!(ec, code::BAD_FRAME),
        other => panic!("expected Error, got {other:?}"),
    }

    // 2) truncated header then hangup: the server just drops the conn
    let mut c = connect(&server);
    c.send_raw(&[0x46, 0x49]).expect("send");
    drop(c);

    // 3) valid header, garbage payload: typed error on an INTACT
    //    connection — the same socket then serves a real solve
    let mut c = connect(&server);
    let hdr = saif::serve::protocol::header(saif::serve::protocol::kind::SOLVE, 4)
        .expect("header");
    let mut frame = hdr.to_vec();
    frame.extend_from_slice(&[9, 9, 9, 9]);
    c.send_raw(&frame).expect("send");
    match c.recv().expect("error reply") {
        Response::Error { .. } => {}
        other => panic!("expected Error for garbage payload, got {other:?}"),
    }
    let pt = solved(c.solve(0, lam, EPS, Method::Saif).expect("solve after bad frame"));
    common::assert_certificate(&prob, &pt.beta, lam, pt.gap, EPS);

    // 4) unknown dataset and invalid λ draw typed errors, not hangs
    match c.solve(99, lam, EPS, Method::Saif).expect("rpc") {
        Response::Error { code: ec, .. } => assert_eq!(ec, code::UNKNOWN_DATASET),
        other => panic!("expected UNKNOWN_DATASET, got {other:?}"),
    }
    match c.request(&Request::Solve {
        dataset: 0,
        lam: -1.0,
        eps: EPS,
        method: Method::Saif,
        loss: saif::model::LossKind::Squared,
        penalty: saif::model::Penalty::default(),
    }) {
        Ok(Response::Error { code: ec, .. }) => assert_eq!(ec, code::BAD_REQUEST),
        other => panic!("expected BAD_REQUEST, got {other:?}"),
    }
    drop(c);

    let stats = server.shutdown();
    assert!(stats.protocol_errors >= 2, "protocol errors must be counted");
}

#[test]
fn poisoned_worker_recovers_without_dropping_accepted_requests() {
    // dataset 0: linear (Saif fine); dataset 1: logistic — Group is
    // LS-only and panics the worker's solve task, poisoning the slot
    let (ds0, prob0) = linear_dataset(0, 19);
    let prob1 = Arc::new(synth::gisette_like(30, 40, 23).problem());
    let ds1 = ServeDataset {
        key: 1,
        name: "logit".into(),
        problem: prob1.clone(),
        tree: None,
    };
    let server = start(test_config(), vec![ds0, ds1]); // workers=1: one slot for both
    let addr = server.local_addr();
    let lam0 = prob0.lambda_max() * 0.3;
    let lam1 = prob1.lambda_max() * 0.5;

    // two clients race: one poisons the slot, one submits good work
    // that must survive the death (resubmitted from the in-flight
    // table after recovery — never silently dropped)
    let outcomes = saif::runtime::pool::scoped_run(2, |ci| {
        let mut client = Client::connect(addr).expect("connect");
        client.set_read_timeout(Some(Duration::from_secs(120))).expect("timeout");
        if ci == 0 {
            client.solve(1, lam1, EPS, Method::Group { size: 4 }).expect("poison rpc")
        } else {
            client.solve(0, lam0, EPS, Method::Saif).expect("good rpc")
        }
    })
    .expect("clients terminate");

    // the poison request gets a typed failure (died twice ⇒ gave up)
    match &outcomes[0] {
        Response::Error { code: ec, .. } => assert_eq!(*ec, code::SOLVE_FAILED),
        other => panic!("poison request: expected SOLVE_FAILED, got {other:?}"),
    }
    // the good request completes with a certificate, whatever the
    // interleaving (before the death, orphaned by it, or after)
    match &outcomes[1] {
        Response::Solved(pt) => {
            common::assert_certificate(&prob0, &pt.beta, lam0, pt.gap, EPS)
        }
        other => panic!("good request: expected Solved, got {other:?}"),
    }

    // the slot respawned cold: the same server keeps serving both
    // datasets after the poison
    let mut client = connect(&server);
    let pt = solved(client.solve(0, lam0 * 0.9, EPS, Method::Saif).expect("post-recovery"));
    common::assert_certificate(&prob0, &pt.beta, lam0 * 0.9, pt.gap, EPS);
    let pt = solved(client.solve(1, lam1, EPS, Method::Saif).expect("poisoned dataset again"));
    common::assert_certificate(&prob1, &pt.beta, lam1, pt.gap, EPS);
    drop(client);

    let stats = server.shutdown();
    assert!(
        stats.total(|d| d.retried) + stats.total(|d| d.errors) >= 1,
        "the death must be visible in the counters"
    );
}

#[test]
fn connection_cap_rejects_with_busy_at_accept() {
    let (ds, _prob) = linear_dataset(0, 29);
    let cfg = ServeConfig { max_conns: 0, ..test_config() };
    let server = start(cfg, vec![ds]);
    let mut c = Client::connect(server.local_addr()).expect("tcp connect still accepts");
    c.set_read_timeout(Some(Duration::from_secs(30))).expect("timeout");
    match c.recv().expect("busy frame") {
        Response::Busy { .. } => {}
        other => panic!("expected Busy at the connection cap, got {other:?}"),
    }
    drop(c);
    let stats = server.shutdown();
    assert!(stats.conns_rejected >= 1);
}

/// Soak: sustained load through repeated start/serve/shutdown cycles.
/// Gated on SAIF_SOAK_SECS (unset ⇒ trivially passes) so CI can run a
/// bounded soak without slowing the default suite.
#[test]
fn soak_runs_until_deadline_when_enabled() {
    let secs: u64 = match std::env::var("SAIF_SOAK_SECS") {
        Ok(s) => s.parse().unwrap_or(0),
        Err(_) => 0,
    };
    if secs == 0 {
        return;
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(secs);
    let mut cycles = 0u64;
    while std::time::Instant::now() < deadline {
        let cfg = saif::serve::bench::BenchServeConfig::quick();
        let res = saif::serve::bench::run(&cfg).expect("soak cycle");
        assert_eq!(res.errors, 0, "soak cycle {cycles} saw request errors");
        cycles += 1;
    }
    assert!(cycles >= 1, "at least one soak cycle must complete");
    println!("soak: {cycles} cycles in {secs}s");
}
