//! Bench: regenerate Figure 7 (tree fused LASSO: SAIF vs ADMM/CVX).
fn main() {
    for id in ["fig7-bc", "fig7-pet"] {
        saif::experiments::run(id, "out").expect("experiment");
    }
}
