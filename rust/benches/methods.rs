//! Benchopt-style method shootout (see `saif::shootout`): every
//! feature-LASSO method over the shared {ls, logit} × {dense, sparse,
//! ooc} λ-path grid, recording wall time + honest certificates +
//! time-to-gap curves to BENCH_methods.json at the repo root, where
//! `tools/bench_guard.py` gates the `_secs` rows like the kernel rows.
//!
//! Run the full grid with `cargo bench --bench methods`; pass
//! `--quick` for the smoke-scale grid.

use saif::shootout;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    match shootout::run(quick) {
        Ok(res) => {
            println!("{}", res.table.render());
            res.table.save_csv("out", "methods_shootout").ok();
            match shootout::write_record(&res.record) {
                Ok(path) => println!("wrote {path}"),
                Err(e) => eprintln!("could not write bench record: {e}"),
            }
        }
        Err(e) => {
            eprintln!("method shootout failed: {e}");
            std::process::exit(1);
        }
    }
}
