//! Bench: coordinator throughput scaling — the L3 serving-layer
//! measurement (workers 1→8 on a fixed multi-tenant λ-path workload),
//! plus the warm-start ablation (affinity on vs scattered keys).

use std::sync::Arc;

use saif::coordinator::{Coordinator, EngineKind, Method, SolveRequest, SolveSpec};
use saif::data::synth;
use saif::metrics::Table;

fn workload(scatter_keys: bool) -> Vec<SolveRequest> {
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for d in 0..4u64 {
        let ds = synth::synth_linear(100, 800, 77 + d);
        let prob = Arc::new(ds.problem());
        let lam_max = prob.lambda_max();
        for k in 1..=6 {
            reqs.push(SolveRequest {
                id,
                // scattered keys disable warm-start reuse/affinity
                dataset_key: if scatter_keys { id } else { d },
                problem: prob.clone(),
                lam: lam_max * (1e-2f64).powf(k as f64 / 6.0),
                method: Method::Saif,
                tree: None,
                warm: None,
                spec: SolveSpec { eps: 1e-6, ..Default::default() },
            });
            id += 1;
        }
    }
    reqs
}

fn main() {
    let mut t = Table::new(
        "coordinator throughput scaling",
        &["workers", "affinity", "wall_s", "req/s", "p50_ms", "p99_ms", "warm_rate"],
    );
    for &workers in &[1usize, 2, 4, 8] {
        for &scatter in &[false, true] {
            let reqs = workload(scatter);
            let total = reqs.len();
            let batch = Coordinator::builder()
                .workers(workers)
                .engine(EngineKind::Native)
                .run_batch(reqs)
                .expect("workers alive");
            let (responses, lat, wall) = (batch.responses, batch.latency, batch.wall_secs);
            let warm = responses.iter().filter(|r| r.warm_started).count();
            t.row(vec![
                workers.to_string(),
                if scatter { "off".into() } else { "on".to_string() },
                format!("{wall:.3}"),
                format!("{:.1}", total as f64 / wall),
                format!("{:.1}", lat.percentile_us(0.5) / 1e3),
                format!("{:.1}", lat.percentile_us(0.99) / 1e3),
                format!("{warm}/{total}"),
            ]);
        }
    }
    println!("{}", t.render());
    t.save_csv("out", "coordinator_scaling").ok();
}
