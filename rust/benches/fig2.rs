//! Bench: regenerate Figure 2 (runtime comparison, sim + breast
//! cancer). `SAIF_FULL=1 cargo bench --bench fig2` for paper scale.
fn main() {
    for id in ["fig2-sim", "fig2-bc"] {
        saif::experiments::run(id, "out").expect("experiment");
    }
}
