//! Loopback load benchmark for the TCP serving front-end (see
//! `saif::serve::bench`): concurrent clients over real sockets drawing
//! λ from a shared grid, so the cache, coalescing, and admission paths
//! all get exercised. Records throughput (`*_rps`), latency
//! percentiles (`*_us`), and the cache counters to BENCH_serve.json at
//! the repo root, where `tools/bench_guard.py` gates them.
//!
//! Run with `cargo bench --bench serve`; pass `--quick` for the
//! CI-sized run.

use saif::serve::bench;

fn main() {
    let cfg = if std::env::args().any(|a| a == "--quick") {
        bench::BenchServeConfig::quick()
    } else {
        bench::BenchServeConfig::default()
    };
    match bench::run(&cfg) {
        Ok(res) => {
            println!(
                "served {} requests in {:.3}s ({:.1} req/s); ok={} busy={} errors={}",
                res.requests, res.wall_secs, res.throughput_rps, res.ok, res.busy, res.errors
            );
            println!(
                "latency p50={:.1}us p99={:.1}us; cache: exact={} certified={} near={} \
                 miss={} coalesced={}",
                res.p50_us,
                res.p99_us,
                res.exact_hits,
                res.certified_hits,
                res.near_refreshes,
                res.misses,
                res.coalesced
            );
            match bench::write_record(&bench::record(&res)) {
                Ok(path) => println!("wrote {path}"),
                Err(e) => eprintln!("could not write bench record: {e}"),
            }
        }
        Err(e) => {
            eprintln!("serve bench failed: {e}");
            std::process::exit(1);
        }
    }
}
