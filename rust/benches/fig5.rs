//! Bench: regenerate Figure 5 (logistic regression runtimes).
fn main() {
    saif::experiments::run("fig5", "out").expect("experiment");
}
