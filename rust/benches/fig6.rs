//! Bench: regenerate Figure 6 (λ-path runtime vs #λ: DPP vs homotopy
//! vs warm-started SAIF).
fn main() {
    saif::experiments::run("fig6", "out").expect("experiment");
}
