//! Micro-benchmarks of the numeric hot paths (EXPERIMENTS.md §Perf):
//!
//! * native dot/axpy (the CM inner loop) at the experiment sizes;
//! * a native CM epoch and screening scan;
//! * the sparse (CSC) vs dense scores scan, serial vs parallel, and
//!   the sharded epoch — each parallel row measured on both threading
//!   substrates (spawn-per-call scoped vs the persistent worker pool)
//!   at p = 10⁴ — recorded to BENCH_kernels.json;
//! * the same sparse scan served out-of-core from a `.saifbin` file
//!   (serial + pooled streaming), quantifying the disk-streaming tax;
//! * the same operations through the PJRT artifacts — call overhead +
//!   the packed-buffer cache effect.

use saif::cm::{Engine, EpochShards, NativeEngine, PoolMode};
use saif::data::synth;
use saif::linalg::{axpy, dot, Design, MixedShadow, Parallelism};
use saif::metrics::Table;
use saif::runtime::{artifacts_available, PjrtEngine};
use saif::solver::{make, Method, SolveSpec, Solver};
use saif::util::bench_secs;
use saif::util::json::Json;
use saif::util::prng::Rng;
use saif::util::Stopwatch;

fn main() {
    let mut t = Table::new(
        "kernel micro-benchmarks",
        &["op", "size", "time", "gflop/s or note"],
    );

    // --- BLAS-1 hot loop ---
    let mut rng = Rng::new(1);
    for n in [100usize, 512, 4096] {
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut sink = 0.0;
        let s = bench_secs(0.2, 1_000_000, || {
            sink += dot(&x, &y);
        });
        t.row(vec![
            "dot".into(),
            n.to_string(),
            format!("{:.1}ns", s * 1e9),
            format!("{:.2}", 2.0 * n as f64 / s / 1e9),
        ]);
        let s = bench_secs(0.2, 1_000_000, || {
            axpy(1.000001, &x, &mut y);
        });
        t.row(vec![
            "axpy".into(),
            n.to_string(),
            format!("{:.1}ns", s * 1e9),
            format!("{:.2}", 2.0 * n as f64 / s / 1e9),
        ]);
        std::hint::black_box(&sink);
        std::hint::black_box(&y);
    }

    // --- CM epoch + scores scan, native vs PJRT ---
    let ds = synth::synth_linear(100, 2000, 3);
    let prob = ds.problem();
    let lam = prob.lambda_max() * 0.05;
    let active: Vec<usize> = (0..200).collect();

    let mut native = NativeEngine::new();
    let mut beta = vec![0.0; active.len()];
    let s = bench_secs(0.3, 10_000, || {
        native.cm_eval(&prob, &active, &mut beta, lam, 10);
    });
    t.row(vec![
        "cm_eval native (10 epochs, |A|=200, n=100)".into(),
        "200".into(),
        format!("{:.2}us", s * 1e6),
        format!("{:.2} (4-flop/coord est)", 10.0 * 200.0 * 100.0 * 4.0 / s / 1e9),
    ]);
    let theta = vec![0.001; prob.n()];
    let s = bench_secs(0.3, 10_000, || {
        std::hint::black_box(native.scores(&prob, &theta));
    });
    t.row(vec![
        "scores native (p=2000, n=100)".into(),
        "2000".into(),
        format!("{:.2}us", s * 1e6),
        format!("{:.2}", 2.0 * 2000.0 * 100.0 / s / 1e9),
    ]);

    // --- sparse vs dense scores scan, serial vs parallel, p = 10⁴ ---
    // The ADD scan is SAIF's O(n·p) hot path; this measures the CSC
    // backend win (scan cost ∝ nnz) and the column-chunked thread win.
    let (n_big, p_big, density) = (256usize, 10_000usize, 0.01f64);
    let dense_prob = synth::synth_linear(n_big, p_big, 5).problem();
    let sparse_ds = synth::synth_sparse(n_big, p_big, density, 5);
    let sparse_prob = sparse_ds.problem();
    let theta_big: Vec<f64> = (0..n_big).map(|j| (j as f64 * 0.13).sin() * 1e-3).collect();
    let hw = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let mut bench_rec = Json::obj();
    bench_rec
        .set("bench", Json::Str("kernels/scores-scan".into()))
        .set("n", Json::Num(n_big as f64))
        .set("p", Json::Num(p_big as f64))
        .set("density", Json::Num(density))
        .set("threads", Json::Num(hw as f64));
    let mut serial_us = [0.0f64; 2];
    for (k, &(label, prob)) in
        [("dense", &dense_prob), ("sparse1pct", &sparse_prob)].iter().enumerate()
    {
        // flops actually executed: 2·nnz (dense nnz = n·p)
        let flops = 2.0 * prob.x.nnz() as f64;
        let mut serial = NativeEngine::new();
        let s = bench_secs(0.3, 2_000, || {
            std::hint::black_box(serial.scores(prob, &theta_big));
        });
        serial_us[k] = s * 1e6;
        t.row(vec![
            format!("scores {label} serial (p={p_big}, n={n_big})"),
            p_big.to_string(),
            format!("{:.2}us", s * 1e6),
            format!("{:.2} GF/s", flops / s / 1e9),
        ]);
        bench_rec.set(&format!("{label}_serial_us"), Json::Num(s * 1e6));

        // spawn-per-call scoped threads (the pre-pool dispatch) vs the
        // persistent worker pool: same bits, different thread source —
        // the delta is pure spawn/park overhead
        let mut par = NativeEngine::with_parallelism(Parallelism::Fixed(hw));
        par.set_pool_mode(PoolMode::Scoped);
        let sp = bench_secs(0.3, 2_000, || {
            std::hint::black_box(par.scores(prob, &theta_big));
        });
        t.row(vec![
            format!("scores {label} scoped x{hw}"),
            p_big.to_string(),
            format!("{:.2}us", sp * 1e6),
            format!("speedup {:.2}x over serial", s / sp),
        ]);
        bench_rec
            .set(&format!("{label}_parallel_us"), Json::Num(sp * 1e6))
            .set(&format!("{label}_parallel_speedup"), Json::Num(s / sp));

        let mut pooled = NativeEngine::with_parallelism(Parallelism::Fixed(hw));
        pooled.set_pool_mode(PoolMode::Persistent);
        let spp = bench_secs(0.3, 2_000, || {
            std::hint::black_box(pooled.scores(prob, &theta_big));
        });
        t.row(vec![
            format!("scores {label} pooled x{hw}"),
            p_big.to_string(),
            format!("{:.2}us", spp * 1e6),
            format!("{:.2}x over scoped", sp / spp),
        ]);
        bench_rec
            .set(&format!("{label}_pooled_us"), Json::Num(spp * 1e6))
            .set(&format!("{label}_pooled_over_scoped"), Json::Num(sp / spp));
    }
    bench_rec.set(
        "sparse_over_dense_serial_speedup",
        Json::Num(serial_us[0] / serial_us[1].max(1e-12)),
    );

    // --- blocked/unrolled kernels vs the scalar baseline they replaced
    // (docs/KERNELS.md). "Unblocked" is the pre-refactor shape: one
    // sequential single-accumulator fold per column, full column at a
    // time. The blocked rows are the shipped kernels — unrolled lanes +
    // COL_STRIP × ROW_BLOCK traversal for dense, 4-lane gather for CSC.
    let dense_mat = match &dense_prob.x {
        Design::Dense(m) => m,
        _ => unreachable!("synth_linear builds a dense design"),
    };
    let mut scan_out = vec![0.0; p_big];
    let s_unb = bench_secs(0.3, 2_000, || {
        for (j, o) in scan_out.iter_mut().enumerate() {
            let c = dense_mat.col(j);
            let mut acc = 0.0;
            for i in 0..n_big {
                acc += c[i] * theta_big[i];
            }
            *o = acc;
        }
        std::hint::black_box(&scan_out);
    });
    let s_blk = bench_secs(0.3, 2_000, || {
        dense_mat.mul_t_vec(&theta_big, &mut scan_out);
        std::hint::black_box(&scan_out);
    });
    t.row(vec![
        format!("Xᵀv dense scalar-fold (p={p_big}, n={n_big})"),
        p_big.to_string(),
        format!("{:.2}us", s_unb * 1e6),
        "pre-blocking baseline".into(),
    ]);
    t.row(vec![
        format!("Xᵀv dense blocked+unrolled (p={p_big}, n={n_big})"),
        p_big.to_string(),
        format!("{:.2}us", s_blk * 1e6),
        format!("speedup {:.2}x over scalar", s_unb / s_blk),
    ]);
    bench_rec
        .set("dense_unblocked_us", Json::Num(s_unb * 1e6))
        .set("dense_blocked_us", Json::Num(s_blk * 1e6))
        .set("dense_blocked_speedup", Json::Num(s_unb / s_blk));

    let sparse_mat = match &sparse_prob.x {
        Design::Sparse(m) => m,
        _ => unreachable!("synth_sparse builds a CSC design"),
    };
    let s_unb = bench_secs(0.3, 2_000, || {
        for (j, o) in scan_out.iter_mut().enumerate() {
            let (rows, vals) = sparse_mat.col(j);
            let mut acc = 0.0;
            for (r, a) in rows.iter().zip(vals) {
                acc += a * theta_big[*r];
            }
            *o = acc;
        }
        std::hint::black_box(&scan_out);
    });
    let s_blk = bench_secs(0.3, 2_000, || {
        sparse_mat.mul_t_vec(&theta_big, &mut scan_out);
        std::hint::black_box(&scan_out);
    });
    t.row(vec![
        format!(
            "Xᵀv csc scalar-gather (p={p_big}, {density:.0}% dense)",
            density = density * 100.0
        ),
        p_big.to_string(),
        format!("{:.2}us", s_unb * 1e6),
        "pre-blocking baseline".into(),
    ]);
    t.row(vec![
        "Xᵀv csc 4-lane gather".into(),
        p_big.to_string(),
        format!("{:.2}us", s_blk * 1e6),
        format!("speedup {:.2}x over scalar", s_unb / s_blk),
    ]);
    bench_rec
        .set("sparse1pct_unblocked_us", Json::Num(s_unb * 1e6))
        .set("sparse1pct_blocked_us", Json::Num(s_blk * 1e6))
        .set("sparse1pct_blocked_speedup", Json::Num(s_unb / s_blk));

    // --- f32 shadow scan vs the f64 scan it may replace (the mixed-
    // precision screening path: scores_upper = f32 scan + certified
    // rounding bound — see linalg/mixed.rs). Shadows are packed once,
    // outside the timer, exactly as the solver amortizes them.
    let mut f64_out = vec![0.0; p_big];
    for (label, x) in [("dense", &dense_prob.x), ("sparse1pct", &sparse_prob.x)] {
        let shadow = MixedShadow::build(x);
        let s64 = bench_secs(0.3, 2_000, || {
            x.mul_t_vec(&theta_big, &mut f64_out);
            std::hint::black_box(&f64_out);
        });
        let s32 = bench_secs(0.3, 2_000, || {
            std::hint::black_box(shadow.scores_upper(&theta_big));
        });
        t.row(vec![
            format!("f32 shadow scan {label} (p={p_big}, n={n_big})"),
            p_big.to_string(),
            format!("{:.2}us", s32 * 1e6),
            format!("{:.2}x of f64 scan ({:.2}us)", s32 / s64, s64 * 1e6),
        ]);
        bench_rec
            .set(&format!("{label}_f32_scan_us"), Json::Num(s32 * 1e6))
            .set(&format!("{label}_f32_scan_speedup"), Json::Num(s64 / s32));
    }

    // --- out-of-core streaming scan: the same sparse problem served
    // from a .saifbin file (Design::OocCsc). The delta over the
    // in-memory CSC rows is the pure disk-streaming tax (page cache
    // warm after the first pass); results are bitwise identical.
    let ooc_path = std::env::temp_dir().join(format!("saif_bench_{}.saifbin", std::process::id()));
    let ooc_path = ooc_path.to_str().expect("utf-8 temp path");
    saif::data::io::write_saifbin(&sparse_ds, ooc_path).expect("write bench saifbin");
    let ooc_prob = saif::data::io::read_saifbin(ooc_path).expect("read bench saifbin").problem();
    let mut ooc_serial = NativeEngine::new();
    let s_ooc = bench_secs(0.3, 2_000, || {
        std::hint::black_box(ooc_serial.scores(&ooc_prob, &theta_big));
    });
    t.row(vec![
        format!("scores ooc-csc serial (p={p_big}, n={n_big})"),
        p_big.to_string(),
        format!("{:.2}us", s_ooc * 1e6),
        format!("{:.2}x of in-memory csc", s_ooc * 1e6 / serial_us[1].max(1e-12)),
    ]);
    let mut ooc_pooled = NativeEngine::with_parallelism(Parallelism::Fixed(hw));
    ooc_pooled.set_pool_mode(PoolMode::Persistent);
    let s_ooc_p = bench_secs(0.3, 2_000, || {
        std::hint::black_box(ooc_pooled.scores(&ooc_prob, &theta_big));
    });
    t.row(vec![
        format!("scores ooc-csc pooled x{hw}"),
        p_big.to_string(),
        format!("{:.2}us", s_ooc_p * 1e6),
        format!("speedup {:.2}x over ooc serial", s_ooc / s_ooc_p),
    ]);
    bench_rec
        .set("ooc_serial_us", Json::Num(s_ooc * 1e6))
        .set("ooc_pooled_us", Json::Num(s_ooc_p * 1e6))
        .set(
            "ooc_over_sparse_serial",
            Json::Num(s_ooc * 1e6 / serial_us[1].max(1e-12)),
        );

    // out-of-core blocked-vs-baseline: the one-pass chunk-budgeted
    // stream (`mul_t_vec`) vs p independent per-column reads
    // (`col_dot` in a loop) — the blocking win here is I/O locality,
    // not FLOPs; both reduce through the same 4-lane gather_dot.
    let s_ooc_unb = bench_secs(0.3, 2_000, || {
        for (j, o) in scan_out.iter_mut().enumerate() {
            *o = ooc_prob.x.col_dot(j, &theta_big);
        }
        std::hint::black_box(&scan_out);
    });
    let s_ooc_blk = bench_secs(0.3, 2_000, || {
        ooc_prob.x.mul_t_vec(&theta_big, &mut scan_out);
        std::hint::black_box(&scan_out);
    });
    t.row(vec![
        format!("Xᵀv ooc-csc per-column reads (p={p_big})"),
        p_big.to_string(),
        format!("{:.2}us", s_ooc_unb * 1e6),
        "pre-blocking baseline".into(),
    ]);
    t.row(vec![
        "Xᵀv ooc-csc chunked stream".into(),
        p_big.to_string(),
        format!("{:.2}us", s_ooc_blk * 1e6),
        format!("speedup {:.2}x over per-column", s_ooc_unb / s_ooc_blk),
    ]);
    bench_rec
        .set("ooc_unblocked_us", Json::Num(s_ooc_unb * 1e6))
        .set("ooc_blocked_us", Json::Num(s_ooc_blk * 1e6))
        .set("ooc_blocked_speedup", Json::Num(s_ooc_unb / s_ooc_blk));

    // f32 shadow of the ooc design: packing streams the file once;
    // every scan after that is in-RAM — the serving amortization the
    // mixed path is built around, so the row measures the scan only.
    let ooc_shadow = MixedShadow::build(&ooc_prob.x);
    let s_ooc_32 = bench_secs(0.3, 2_000, || {
        std::hint::black_box(ooc_shadow.scores_upper(&theta_big));
    });
    t.row(vec![
        format!("f32 shadow scan ooc-csc (p={p_big})"),
        p_big.to_string(),
        format!("{:.2}us", s_ooc_32 * 1e6),
        format!("{:.2}x of streamed f64 scan", s_ooc_32 / s_ooc_blk),
    ]);
    bench_rec
        .set("ooc_f32_scan_us", Json::Num(s_ooc_32 * 1e6))
        .set("ooc_f32_scan_speedup", Json::Num(s_ooc_blk / s_ooc_32));
    std::fs::remove_file(ooc_path).ok();

    // --- serial vs sharded active-block CM epoch, |A| = 2000 ---
    // The reduced-model epoch is SAIF's hot path once |A| grows; this
    // measures the Jacobi-shard + ordered-residual-merge win over the
    // serial Gauss–Seidel sweep at a Gisette-scale active block.
    let wide_active: Vec<usize> = (0..2000.min(p_big)).collect();
    let lam_big = dense_prob.lambda_max() * 0.05;
    let mut beta_ser = vec![0.0; wide_active.len()];
    let mut epoch_serial = NativeEngine::new();
    let s_ser = bench_secs(0.3, 2_000, || {
        epoch_serial.cm_eval(&dense_prob, &wide_active, &mut beta_ser, lam_big, 1);
    });
    t.row(vec![
        format!("cm epoch serial (|A|={}, n={n_big})", wide_active.len()),
        wide_active.len().to_string(),
        format!("{:.2}us", s_ser * 1e6),
        "1 epoch + gap eval".into(),
    ]);
    bench_rec.set("epoch_serial_us", Json::Num(s_ser * 1e6));
    let mut beta_sh = vec![0.0; wide_active.len()];
    let mut epoch_sharded = NativeEngine::new();
    epoch_sharded.set_epoch_shards(EpochShards::Fixed(hw));
    epoch_sharded.set_pool_mode(PoolMode::Scoped);
    let s_sh = bench_secs(0.3, 2_000, || {
        epoch_sharded.cm_eval(&dense_prob, &wide_active, &mut beta_sh, lam_big, 1);
    });
    t.row(vec![
        format!("cm epoch sharded x{hw} scoped (|A|={}, n={n_big})", wide_active.len()),
        wide_active.len().to_string(),
        format!("{:.2}us", s_sh * 1e6),
        format!("speedup {:.2}x over serial", s_ser / s_sh),
    ]);
    bench_rec
        .set("epoch_sharded_us", Json::Num(s_sh * 1e6))
        .set("epoch_shards", Json::Num(hw as f64))
        .set("epoch_shard_speedup", Json::Num(s_ser / s_sh));
    // the per-epoch thread-spawn tax the persistent pool removes: the
    // sharded epoch is dispatched thousands of times per solve, so
    // this row is the one the pooled runtime exists for
    let mut beta_pl = vec![0.0; wide_active.len()];
    let mut epoch_pooled = NativeEngine::new();
    epoch_pooled.set_epoch_shards(EpochShards::Fixed(hw));
    epoch_pooled.set_pool_mode(PoolMode::Persistent);
    let s_pl = bench_secs(0.3, 2_000, || {
        epoch_pooled.cm_eval(&dense_prob, &wide_active, &mut beta_pl, lam_big, 1);
    });
    t.row(vec![
        format!("cm epoch sharded x{hw} pooled (|A|={}, n={n_big})", wide_active.len()),
        wide_active.len().to_string(),
        format!("{:.2}us", s_pl * 1e6),
        format!("{:.2}x over scoped", s_sh / s_pl),
    ]);
    bench_rec
        .set("epoch_pooled_us", Json::Num(s_pl * 1e6))
        .set("epoch_pooled_over_scoped", Json::Num(s_sh / s_pl));

    // --- λ-path sweep: 64 points, independent solves vs one
    // warm-chained `Solver::path` session (the Figure-6 trick behind
    // the unified solver API) ---
    let path_prob = synth::synth_linear(100, 1500, 7).problem();
    let lam_max_p = path_prob.lambda_max();
    let n_pts = 64usize;
    let grid: Vec<f64> = (1..=n_pts)
        .map(|k| lam_max_p * (1e-2f64).powf(k as f64 / n_pts as f64))
        .collect();
    let spec = SolveSpec { eps: 1e-6, ..Default::default() };
    let sw = Stopwatch::start();
    {
        let mut eng = NativeEngine::new();
        let mut s = make(Method::Saif, &mut eng, &spec);
        for &lam in &grid {
            std::hint::black_box(s.solve(&path_prob, lam));
        }
    }
    let s_cold = sw.secs();
    let sw = Stopwatch::start();
    {
        let mut eng = NativeEngine::new();
        std::hint::black_box(make(Method::Saif, &mut eng, &spec).path(&path_prob, &grid));
    }
    let s_warm = sw.secs();
    t.row(vec![
        format!("saif path_{n_pts}pts serial (p=1500, n=100)"),
        n_pts.to_string(),
        format!("{:.1}ms", s_cold * 1e3),
        "independent per-λ solves".into(),
    ]);
    t.row(vec![
        format!("saif path_{n_pts}pts warm-chained"),
        n_pts.to_string(),
        format!("{:.1}ms", s_warm * 1e3),
        format!("speedup {:.2}x over serial", s_cold / s_warm),
    ]);
    bench_rec
        .set("path64_serial_ms", Json::Num(s_cold * 1e3))
        .set("path64_warm_ms", Json::Num(s_warm * 1e3))
        .set("path64_warm_speedup", Json::Num(s_cold / s_warm));
    // repo root, independent of the invocation CWD
    let bench_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json");
    match std::fs::write(bench_path, bench_rec.to_string() + "\n") {
        Ok(()) => println!("wrote {bench_path}"),
        Err(e) => eprintln!("could not write {bench_path}: {e}"),
    }

    if artifacts_available() {
        let mut pjrt = PjrtEngine::new().expect("pjrt");
        let mut beta2 = vec![0.0; active.len()];
        let s = bench_secs(0.5, 5_000, || {
            pjrt.cm_eval(&prob, &active, &mut beta2, lam, 10);
        });
        t.row(vec![
            "cm_eval pjrt (bucket 128x256)".into(),
            "200".into(),
            format!("{:.2}us", s * 1e6),
            "AOT artifact call incl. padding+transfer".into(),
        ]);
        let s = bench_secs(0.5, 5_000, || {
            std::hint::black_box(pjrt.scores(&prob, &theta));
        });
        t.row(vec![
            "scores pjrt (bucket 128x5120, cached pack)".into(),
            "2000".into(),
            format!("{:.2}us", s * 1e6),
            "AOT artifact call".into(),
        ]);
    } else {
        t.row(vec![
            "pjrt".into(),
            "-".into(),
            "skipped".into(),
            "artifacts not built".into(),
        ]);
    }

    println!("{}", t.render());
    t.save_csv("out", "kernels_micro").ok();
}
