//! Bench: regenerate Table 1 (homotopy recall/precision vs SAIF).
fn main() {
    saif::experiments::run("table1", "out").expect("experiment");
}
