//! Quickstart: generate a synthetic p ≫ n dataset, solve the LASSO
//! with SAIF, verify safety with a KKT certificate, and compare
//! against the no-screening baseline.
//!
//!   cargo run --release --example quickstart

use saif::cm::{solve_subproblem, NativeEngine};
use saif::data::synth;
use saif::saif::{Saif, SaifConfig};
use saif::util::Stopwatch;

fn main() {
    // 1. a p >> n problem: 100 samples, 5000 features
    let ds = synth::synth_linear(100, 5000, 7);
    let prob = ds.problem();
    let lam_max = prob.lambda_max();
    let lam = lam_max * 0.01;
    println!("dataset {} (n={}, p={}), λ_max = {lam_max:.3e}, λ = {lam:.3e}", ds.name, prob.n(), prob.p());

    // 2. SAIF solve
    let mut eng = NativeEngine::new();
    let mut solver = Saif::new(&mut eng, SaifConfig { eps: 1e-8, ..Default::default() });
    let res = solver.solve(&prob, lam);
    println!(
        "SAIF: {} nonzeros in {:.3}s — touched at most {} of {} features (gap {:.1e})",
        res.beta.len(), res.secs, res.max_active, prob.p(), res.gap
    );

    // 3. safety certificate: KKT of the FULL problem
    let kkt = prob.kkt_violation(&res.beta, lam);
    println!("KKT violation: {kkt:.2e} (0 ⇒ certified optimal)");
    assert!(kkt < 1e-3);

    // 4. compare with solving the full problem (no screening)
    let sw = Stopwatch::start();
    let all: Vec<usize> = (0..prob.p()).collect();
    let mut beta_full = vec![0.0; prob.p()];
    let mut eng2 = NativeEngine::new();
    let (eval, _) = solve_subproblem(&mut eng2, &prob, &all, &mut beta_full, lam, 1e-8, 10, 200_000);
    let full_secs = sw.secs();
    println!(
        "no-screening: same gap ({:.1e}) in {:.3}s — SAIF speedup {:.0}x",
        eval.gap, full_secs, full_secs / res.secs.max(1e-9)
    );

    // solutions agree
    for &(i, b) in &res.beta {
        assert!((beta_full[i] - b).abs() < 1e-4 * b.abs().max(1.0));
    }
    println!("solutions agree. done.");
}
