//! Perf probe (EXPERIMENTS.md §Perf): times cm_eval vs scores vs
//! coordination on the fig2-sim workload through a delegating Engine.
use std::time::Instant;
use saif::cm::{Engine, NativeEngine, SubEval};
use saif::data::synth;
use saif::model::Problem;
use saif::saif::{Saif, SaifConfig};

struct Probe {
    inner: NativeEngine,
    cm_secs: f64,
    cm_calls: usize,
    sc_secs: f64,
    sc_calls: usize,
}
impl Engine for Probe {
    fn cm_eval(&mut self, p: &Problem, a: &[usize], b: &mut [f64], l: f64, k: usize) -> SubEval {
        let t = Instant::now();
        let r = self.inner.cm_eval(p, a, b, l, k);
        self.cm_secs += t.elapsed().as_secs_f64();
        self.cm_calls += 1;
        r
    }
    fn scores(&mut self, p: &Problem, th: &[f64]) -> Vec<f64> {
        let t = Instant::now();
        let r = self.inner.scores(p, th);
        self.sc_secs += t.elapsed().as_secs_f64();
        self.sc_calls += 1;
        r
    }
    fn name(&self) -> &'static str { "probe" }
}

fn main() {
    let ds = synth::synth_linear(100, 2000, 42);
    let prob = ds.problem();
    let lam_max = prob.lambda_max();
    for frac in [5e-3, 1e-3f64] {
        let lam = lam_max * frac;
        let mut probe = Probe { inner: NativeEngine::new(), cm_secs: 0.0, cm_calls: 0, sc_secs: 0.0, sc_calls: 0 };
        let t = Instant::now();
        let mut s = Saif::new(&mut probe, SaifConfig { eps: 1e-6, ..Default::default() });
        let r = s.solve(&prob, lam);
        let total = t.elapsed().as_secs_f64();
        println!("frac={frac:.0e}: total={total:.3}s outer={} epochs={} p_add={} max_act={} final_act={} gap={:.1e}",
            r.outer_iters, r.epochs, r.p_add_total, r.max_active, r.final_active, r.gap);
        println!("  cm_eval: {:.3}s over {} calls | scores: {:.3}s over {} calls | other {:.3}s",
            probe.cm_secs, probe.cm_calls, probe.sc_secs, probe.sc_calls, total - probe.cm_secs - probe.sc_secs);
    }
}
