//! Tree fused LASSO (paper §4 / Figure 7): fuse gene-expression
//! features along a PPI-like interaction tree, solve with SAIF on the
//! Theorem-6 transformed problem, and cross-check objective parity
//! with the generic ADMM solver (the CVX stand-in).
//!
//!   cargo run --release --example fused_tree

use saif::cm::NativeEngine;
use saif::data::{synth, tree};
use saif::fused::{fused_objective, FusedAdmm, FusedSaif, FusedSaifConfig};
use saif::model::LossKind;
use saif::saif::SaifConfig;

fn main() {
    let (n, p) = (96, 1500);
    let ds = synth::gene_expr(n, p, 13);
    let edges = tree::preferential_attachment(p, 13);
    let lam_max = FusedSaif::lambda_max(ds.x.as_dense(), &ds.y, LossKind::Squared, &edges).unwrap();
    let lam = lam_max * 0.1;
    println!("fused LASSO: n={n}, p={p}, tree edges={}, λ = {lam:.3e} (0.1 λ_max)", edges.len());

    let mut eng = NativeEngine::new();
    let mut fs = FusedSaif::new(
        &mut eng,
        FusedSaifConfig { saif: SaifConfig { eps: 1e-8, ..Default::default() }, ..Default::default() },
    );
    let res = fs.solve(ds.x.as_dense(), &ds.y, LossKind::Squared, &edges, lam).unwrap();
    let n_groups = {
        // count distinct fused levels along the tree
        let mut distinct = 1;
        for &(a, b) in &edges {
            if (res.beta[a] - res.beta[b]).abs() > 1e-8 {
                distinct += 1;
            }
        }
        distinct
    };
    println!(
        "SAIF: objective {:.6} in {:.3}s (gap {:.1e}); {} fused groups; touched ≤ {} of {} edge vars",
        res.objective, res.secs, res.gap, n_groups, res.max_active, p - 1
    );

    let mut admm = FusedAdmm::new(Default::default());
    let target = res.objective * (1.0 + 1e-6);
    let ares = admm.solve(ds.x.as_dense(), &ds.y, LossKind::Squared, &edges, lam, Some(target));
    println!(
        "ADMM (CVX stand-in): objective {:.6} in {:.3}s ({} iters) — SAIF speedup {:.0}x",
        ares.objective, ares.secs, ares.iters, ares.secs / res.secs.max(1e-9)
    );
    let check = fused_objective(ds.x.as_dense(), &ds.y, LossKind::Squared, &edges, &res.beta, lam);
    assert!((check - res.objective).abs() < 1e-9);
    assert!(ares.objective >= res.objective - 1e-6 * res.objective.abs());
    println!("objective parity verified. done.");
}
