//! λ-path workload (paper §5.3 / Figure 6): solve a descending λ grid
//! with warm-started SAIF and compare against DPP sequential screening
//! and the (unsafe) homotopy method, reporting per-method path time
//! and the homotopy method's support-recovery errors.
//!
//!   cargo run --release --example lambda_path [n_lambdas]

use saif::cm::NativeEngine;
use saif::data::synth;
use saif::homotopy::{recall_precision, Homotopy, HomotopyConfig};
use saif::screening::dpp::DppPath;
use saif::solver::{make, Method, SolveSpec, Solver};

fn main() {
    let n_lam: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50);
    let ds = synth::synth_linear(100, 2000, 11);
    let prob = ds.problem();
    let lam_max = prob.lambda_max();
    let lams: Vec<f64> = (1..=n_lam)
        .map(|k| lam_max * (1e-3f64).powf(k as f64 / n_lam as f64))
        .collect();
    println!("{} λ values in [{:.2e}, {:.2e}], eps 1e-6", n_lam, lams[n_lam - 1], lams[0]);

    // SAIF λ-path session (warm-chained behind the unified Solver API)
    let mut eng = NativeEngine::new();
    let spec = SolveSpec { eps: 1e-6, ..Default::default() };
    let path = make(Method::Saif, &mut eng, &spec).path(&prob, &lams);
    let saif_supports: Vec<Vec<usize>> = path
        .points
        .iter()
        .map(|sol| sol.beta.iter().map(|&(i, _)| i).collect())
        .collect();
    println!("SAIF(warm):  {:.3}s", path.secs);

    // DPP sequential screening
    let mut eng2 = NativeEngine::new();
    let (_steps, dpp_secs) = DppPath::new(&mut eng2, 1e-6).solve_path(&prob, &lams);
    println!("DPP:         {dpp_secs:.3}s");

    // homotopy (unsafe)
    let mut eng3 = NativeEngine::new();
    let mut hom = Homotopy::new(&mut eng3, HomotopyConfig::default());
    let (hsteps, hom_secs) = hom.solve_path(&prob, &lams);
    println!("homotopy:    {hom_secs:.3}s (no safe guarantee)");

    // support recovery of homotopy vs SAIF's certified supports
    let mut worst_recall: f64 = 1.0;
    let mut worst_prec: f64 = 1.0;
    for (k, step) in hsteps.iter().enumerate() {
        let found: Vec<usize> = step.beta.iter().map(|&(i, _)| i).collect();
        let (r, p) = recall_precision(&found, &saif_supports[k]);
        worst_recall = worst_recall.min(r);
        worst_prec = worst_prec.min(p);
    }
    println!("homotopy support recovery across the path: worst recall {worst_recall:.3}, worst precision {worst_prec:.3}");
    println!("SAIF recall/precision: 1.000/1.000 (safe guarantee, KKT-certified)");
}
