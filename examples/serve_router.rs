//! END-TO-END DRIVER (DESIGN.md §7): the full three-layer stack on a
//! realistic serving workload.
//!
//! A multi-tenant batch of LASSO solve requests — several datasets,
//! each with a descending λ path (the cross-validation workload of
//! paper §5.3) — is pushed through the L3 coordinator. Workers use
//! the **PJRT engine**, i.e. every CM epoch, duality-gap evaluation
//! and screening scan executes inside the AOT-compiled JAX/Pallas
//! artifacts; Python is not running. Each response is KKT-certified
//! by the coordinator against the full problem in f64.
//!
//! Reports throughput, latency percentiles, warm-start rate and the
//! worst safety certificate — recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example serve_router [workers] [datasets] [lambdas]

use std::sync::Arc;

use saif::coordinator::{Coordinator, EngineKind, Method, SolveRequest, SolveSpec};
use saif::data::synth;
use saif::runtime::artifacts_available;

fn main() {
    let mut args = std::env::args().skip(1);
    let workers: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4);
    let n_datasets: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4);
    let n_lambdas: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(8);

    let engine = if artifacts_available() {
        println!("engine: PJRT (AOT JAX/Pallas artifacts)");
        EngineKind::Pjrt
    } else {
        println!("engine: native (artifacts not built — run `make artifacts` for the full stack)");
        EngineKind::Native
    };

    // multi-tenant workload: distinct datasets × descending-λ paths
    let mut requests = Vec::new();
    let mut id = 0u64;
    for d in 0..n_datasets {
        let ds = synth::synth_linear(100, 1000 + 500 * (d % 3), 9000 + d as u64);
        let prob = Arc::new(ds.problem());
        let lam_max = prob.lambda_max();
        for k in 1..=n_lambdas {
            requests.push(SolveRequest {
                id,
                dataset_key: d as u64,
                problem: prob.clone(),
                lam: lam_max * (2e-2f64).powf(k as f64 / n_lambdas as f64),
                method: Method::Saif,
                tree: None,
                warm: None,
                spec: SolveSpec {
                    // f32 artifacts: gap floor ~1e-4 relative here
                    eps: if engine == EngineKind::Pjrt { 1e-2 } else { 1e-6 },
                    ..Default::default()
                },
            });
            id += 1;
        }
    }
    let total = requests.len();
    println!("workload: {n_datasets} datasets × {n_lambdas} λ = {total} requests, {workers} workers");

    let batch = Coordinator::builder()
        .workers(workers)
        .engine(engine)
        .run_batch(requests)
        .expect("coordinator workers alive");
    let (responses, lat, wall) = (batch.responses, batch.latency, batch.wall_secs);

    assert_eq!(responses.len(), total);
    let warm = responses.iter().filter(|r| r.warm_started).count();
    let worst_rel_kkt = responses
        .iter()
        .map(|r| r.kkt_violation / r.lam.max(1.0))
        .fold(0.0f64, f64::max);
    let nz_total: usize = responses.iter().map(|r| r.beta.len()).sum();

    println!("----------------------------------------------------------");
    println!("completed:   {total} requests in {wall:.3}s  ({:.1} req/s)", total as f64 / wall);
    println!("latency:     {}", lat.summary());
    println!("warm-start:  {warm}/{total} requests reused a path predecessor");
    println!("safety:      worst relative KKT violation {worst_rel_kkt:.2e} (coordinator-verified)");
    println!("solutions:   {nz_total} nonzero coefficients across all responses");
    assert!(
        worst_rel_kkt < 1e-2,
        "safety certificate failed: {worst_rel_kkt:.2e}"
    );
    println!("END-TO-END OK: L3 coordinator → PJRT runtime → AOT JAX/Pallas kernels");
}
