//! `vet` — the saif in-tree invariant linter.
//!
//! Lexes every `.rs` file under a root directory with a comment/string-aware
//! line scanner (no `syn`, no regex crate, no dependencies at all) and
//! enforces the crate's written invariants as deny-by-default lints:
//!
//! - `thread-spawn` (L1): `thread::spawn` / `thread::scope` / `thread::Builder`
//!   are forbidden outside `runtime/` — all parallelism goes through
//!   `runtime::pool::WorkerPool` so ordering stays deterministic.
//! - `undocumented-unsafe` (L2): every `unsafe` keyword (blocks *and*
//!   `unsafe impl`) must carry a `SAFETY:` comment within the 5 lines above.
//! - `unordered-map` (L3): no `HashMap` / `HashSet` in result-producing
//!   modules (`solver`, `cm`, `saif`, `screening`, `coordinator`, `linalg`,
//!   `serve`) — unordered iteration is how determinism dies silently.
//! - `non-total-order` (L4): no `partial_cmp` and no `f64::max` / `f64::min`
//!   folds on possibly-NaN data — use `total_cmp` (see `util::order`).
//!   Unlike the other conditional lints this one applies in `#[cfg(test)]`
//!   regions too: a NaN-lossy comparison in a test silently weakens the
//!   assertion it feeds (sites where the lossy fold is intended carry a
//!   reasoned waiver).
//! - `unchecked-cast` (L5): no bare `as usize` / `as u64` casts in the
//!   untrusted-input decoders — the `.saifbin` header/offset readers
//!   (`data/io.rs`, `linalg/ooc.rs`) and the serving wire-protocol codec
//!   (`serve/protocol.rs`) — use `try_from` or checked arithmetic there.
//! - `lib-panic` (L6): no `.unwrap()` / `.expect(` / `panic!` in library
//!   code outside `#[cfg(test)]` regions (the poison-recovery idiom
//!   `unwrap_or_else(|e| e.into_inner())` contains no banned token and
//!   passes by construction).
//! - `mixed-precision-confined` (L7): no `f32` tokens (the type, casts,
//!   or literal suffixes like `1.0f32`) in the result-producing modules
//!   outside `linalg/mixed.rs` — the one sanctioned low-precision path
//!   is the f32 screening shadow, whose rounding error is provably
//!   absorbed into the ball-test margin (docs/KERNELS.md). An `f32`
//!   anywhere else in the solver stack would corrupt f64 certificates
//!   silently. `Precision::MixedF32` and the `"mixed-f32"` CLI string
//!   never match: the token search is case-sensitive, word-boundary
//!   aware, and blind inside strings and comments.
//!
//! Waivers are per-site comments with a mandatory reason:
//!
//! ```text
//! // vet: allow(lib-panic): re-raises a worker panic; no Result channel here
//! // vet: allow-file(lib-panic): feature-gated experimental bridge
//! ```
//!
//! `allow(..)` covers findings on its own line (trailing comment) or, when it
//! sits on a comment-only line, the next line that carries code.
//! `allow-file(..)` covers the whole file for the named lints. A waiver with
//! an unknown lint name or an empty reason is itself a finding
//! (`bad-waiver`), and a waiver that matches nothing is `unused-waiver`, so
//! stale annotations cannot accumulate.
//!
//! Usage: `vet [--json] [ROOT]` (ROOT defaults to `rust/src`).
//! Exit codes: 0 = clean, 1 = findings, 2 = usage or I/O error.

use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const LINTS: [&str; 7] = [
    "thread-spawn",
    "undocumented-unsafe",
    "unordered-map",
    "non-total-order",
    "unchecked-cast",
    "lib-panic",
    "mixed-precision-confined",
];

/// Modules whose output feeds solver results; L3 applies only here.
/// `serve` qualifies because its λ-grid cache and in-flight table decide
/// which β bytes clients receive.
const RESULT_MODULES: [&str; 7] =
    ["solver", "cm", "saif", "screening", "coordinator", "linalg", "serve"];

/// Files doing untrusted header/offset decoding; L5 applies only here.
const CAST_FILES: [&str; 3] = ["data/io.rs", "linalg/ooc.rs", "serve/protocol.rs"];

/// The one file where `f32` is sanctioned (L7): the screening shadow,
/// whose rounding error is certified into the ball-test margin.
const F32_SANCTUARY: &str = "linalg/mixed.rs";

/// Binary-facing top-level modules where process-exiting panics are the
/// error channel; L6 does not apply (nor to `main.rs`).
const PANIC_EXEMPT_TOP: [&str; 2] = ["cli", "experiments"];

/// How many lines above an `unsafe` keyword a `SAFETY:` comment may sit.
const SAFETY_WINDOW: usize = 5;

#[derive(Debug)]
struct Finding {
    file: String,
    line: usize,
    lint: String,
    msg: String,
}

struct Waiver {
    line: usize,
    lints: Vec<String>,
    reason_ok: bool,
    names_ok: bool,
    file_scope: bool,
    used: bool,
}

// ---------------------------------------------------------------------------
// Lexer: split source into per-line (code, comment) pairs.  `code` has
// comments removed and string/char contents blanked to spaces (delimiters
// kept), so token matching never fires inside literals or comments.
// ---------------------------------------------------------------------------

enum LexState {
    Code,
    Block,
    Str,
    RawStr,
    CharLit,
}

fn split_lines(src: &str) -> Vec<(String, String)> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = LexState::Code;
    let mut depth = 0usize;
    let mut hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        let nxt = if i + 1 < n { cs[i + 1] } else { '\0' };
        if c == '\n' {
            out.push((std::mem::take(&mut code), std::mem::take(&mut comment)));
            i += 1;
            continue;
        }
        match state {
            LexState::Code => {
                if c == '/' && nxt == '/' {
                    while i < n && cs[i] != '\n' {
                        comment.push(cs[i]);
                        i += 1;
                    }
                } else if c == '/' && nxt == '*' {
                    state = LexState::Block;
                    depth = 1;
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = LexState::Str;
                    i += 1;
                } else if c == 'r' && (nxt == '"' || nxt == '#') {
                    // raw string r"..." or r#"..."# (or a raw identifier r#x)
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        code.push('r');
                        for _ in 0..h {
                            code.push('#');
                        }
                        code.push('"');
                        hashes = h;
                        state = LexState::RawStr;
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == 'b' && nxt == '"' {
                    code.push_str("b\"");
                    state = LexState::Str;
                    i += 2;
                } else if c == '\'' {
                    // char literal vs lifetime
                    if nxt == '\\' {
                        code.push('\'');
                        state = LexState::CharLit;
                        i += 1;
                    } else if i + 2 < n && cs[i + 2] == '\'' && nxt != '\'' {
                        code.push_str("' '");
                        i += 3;
                    } else {
                        code.push('\''); // lifetime
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            LexState::Block => {
                if c == '/' && nxt == '*' {
                    depth += 1;
                    comment.push_str("/*");
                    i += 2;
                } else if c == '*' && nxt == '/' {
                    depth -= 1;
                    comment.push_str("*/");
                    i += 2;
                    if depth == 0 {
                        state = LexState::Code;
                    }
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            LexState::Str => {
                if c == '\\' && nxt != '\n' {
                    code.push(' ');
                    i += 2;
                } else if c == '\\' {
                    // escaped-newline continuation: keep the newline visible
                    // to the line splitter so line numbers stay aligned
                    code.push(' ');
                    i += 1;
                } else if c == '"' {
                    code.push('"');
                    state = LexState::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            LexState::RawStr => {
                let closes = c == '"'
                    && i + hashes < n
                    && cs[i + 1..i + 1 + hashes].iter().all(|&h| h == '#');
                if closes {
                    code.push('"');
                    for _ in 0..hashes {
                        code.push('#');
                    }
                    i += 1 + hashes;
                    state = LexState::Code;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            LexState::CharLit => {
                if c == '\\' && nxt != '\n' {
                    code.push(' ');
                    i += 2;
                } else if c == '\'' {
                    code.push('\'');
                    state = LexState::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    out.push((code, comment));
    out
}

// ---------------------------------------------------------------------------
// Token matchers (word-boundary aware, on blanked code text).
// ---------------------------------------------------------------------------

fn is_word(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn boundary_before(code: &str, at: usize) -> bool {
    code[..at].chars().next_back().map_or(true, |c| !is_word(c))
}

fn boundary_after(code: &str, end: usize) -> bool {
    code[end..].chars().next().map_or(true, |c| !is_word(c))
}

/// Whole-identifier occurrence of `word` in `code`.
fn find_word(code: &str, word: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(word) {
        let abs = start + pos;
        if boundary_before(code, abs) && boundary_after(code, abs + word.len()) {
            return true;
        }
        start = abs + word.len();
    }
    false
}

/// L1: `thread::spawn` / `thread::scope` / `thread::Builder`.
fn hit_thread(code: &str) -> bool {
    const PREFIX: &str = "thread::";
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(PREFIX) {
        let abs = start + pos;
        if boundary_before(code, abs) {
            let rest = &code[abs + PREFIX.len()..];
            for w in ["spawn", "scope", "Builder"] {
                if rest.starts_with(w) && boundary_after(rest, w.len()) {
                    return true;
                }
            }
        }
        start = abs + PREFIX.len();
    }
    false
}

/// L4: `partial_cmp`, or `f64::max` / `f64::min` (the fold functions; the
/// constants `f64::MAX` / `f64::MIN` differ in case and never match).
fn hit_order(code: &str) -> bool {
    if find_word(code, "partial_cmp") {
        return true;
    }
    for pat in ["f64::max", "f64::min"] {
        let mut start = 0usize;
        while let Some(pos) = code[start..].find(pat) {
            let abs = start + pos;
            if boundary_after(code, abs + pat.len()) {
                return true;
            }
            start = abs + pat.len();
        }
    }
    false
}

/// L5: bare `as usize` / `as u64`.
fn hit_cast(code: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find("as") {
        let abs = start + pos;
        start = abs + 2;
        if !boundary_before(code, abs) {
            continue;
        }
        let rest = &code[abs + 2..];
        let trimmed = rest.trim_start();
        if trimmed.len() == rest.len() {
            continue; // no whitespace after `as` => part of another token
        }
        for w in ["usize", "u64"] {
            if trimmed.starts_with(w) && boundary_after(trimmed, w.len()) {
                return true;
            }
        }
    }
    false
}

/// L7: any `f32` token — as a whole identifier (`f32::`, `as f32`,
/// `Vec<f32>`) or as a numeric-literal suffix (`1.0f32`, `7f32`, where
/// the preceding digit/dot defeats the word boundary). `MixedF32` and
/// string/comment occurrences never reach here (case-sensitive search
/// on blanked code text).
fn hit_f32(code: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find("f32") {
        let abs = start + pos;
        start = abs + 3;
        if !boundary_after(code, abs + 3) {
            continue;
        }
        if boundary_before(code, abs) {
            return true;
        }
        let prev = code[..abs].chars().next_back();
        if prev.map_or(false, |c| c.is_ascii_digit() || c == '.') {
            return true; // literal suffix: 1.0f32 / 7f32
        }
    }
    false
}

/// L6: `.unwrap()` / `.expect(` / `panic!(`.
fn hit_panic(code: &str) -> bool {
    if code.contains(".unwrap()") || code.contains(".expect(") {
        return true;
    }
    let mut start = 0usize;
    while let Some(pos) = code[start..].find("panic!") {
        let abs = start + pos;
        start = abs + 6;
        if boundary_before(code, abs) && code[abs + 6..].trim_start().starts_with('(') {
            return true;
        }
    }
    false
}

fn is_test_attr(code: &str) -> bool {
    let squashed: String = code.chars().filter(|c| !c.is_whitespace()).collect();
    squashed.contains("#[cfg(test)]") || squashed.contains("#[test]")
}

fn has_safety(comment: &str) -> bool {
    comment.contains("SAFETY:") || comment.contains("# Safety")
}

/// Parse `vet: allow(<lints>): <reason>` or `vet: allow-file(...)` out of a
/// comment. Returns (lint names, reason, file_scope).
fn parse_waiver(comment: &str) -> Option<(Vec<String>, String, bool)> {
    let pos = comment.find("vet:")?;
    let rest = comment[pos + 4..].trim_start();
    let (file_scope, rest) = if let Some(r) = rest.strip_prefix("allow-file(") {
        (true, r)
    } else if let Some(r) = rest.strip_prefix("allow(") {
        (false, r)
    } else {
        return None;
    };
    let close = rest.find(')')?;
    let names: Vec<String> = rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':')?.trim().to_string();
    Some((names, reason, file_scope))
}

// ---------------------------------------------------------------------------
// Per-file scan.
// ---------------------------------------------------------------------------

fn scan_file(relpath: &str, src: &str, findings: &mut Vec<Finding>) {
    let lines = split_lines(src);
    let top = relpath.split('/').next().unwrap_or(relpath);
    let l1_on = top != "runtime";
    let l3_on = RESULT_MODULES.contains(&top);
    let l5_on = CAST_FILES.contains(&relpath);
    let l6_on = !PANIC_EXEMPT_TOP.contains(&top) && relpath != "main.rs";
    let l7_on = RESULT_MODULES.contains(&top) && relpath != F32_SANCTUARY;

    // Collect waivers (and waiver-syntax findings) first.
    let mut waivers: Vec<Waiver> = Vec::new();
    for (idx, (_, comment)) in lines.iter().enumerate() {
        let Some((names, reason, file_scope)) = parse_waiver(comment) else {
            continue;
        };
        let mut names_ok = true;
        for nm in &names {
            if !LINTS.contains(&nm.as_str()) {
                names_ok = false;
                findings.push(Finding {
                    file: relpath.to_string(),
                    line: idx + 1,
                    lint: "bad-waiver".to_string(),
                    msg: format!("unknown lint '{nm}' in waiver"),
                });
            }
        }
        if reason.is_empty() {
            findings.push(Finding {
                file: relpath.to_string(),
                line: idx + 1,
                lint: "bad-waiver".to_string(),
                msg: "waiver without a reason".to_string(),
            });
        }
        waivers.push(Waiver {
            line: idx,
            lints: names,
            reason_ok: !reason.is_empty(),
            names_ok,
            file_scope,
            used: false,
        });
    }

    // A line waiver targets its own line if that line carries code, else the
    // next line that does.
    let code_lines: Vec<usize> = lines
        .iter()
        .enumerate()
        .filter(|(_, (code, _))| !code.trim().is_empty())
        .map(|(idx, _)| idx)
        .collect();
    let mut target_of: Vec<Option<usize>> = Vec::with_capacity(waivers.len());
    for w in &waivers {
        if w.file_scope {
            target_of.push(None);
        } else if !lines[w.line].0.trim().is_empty() {
            target_of.push(Some(w.line));
        } else {
            target_of.push(code_lines.iter().copied().find(|&l| l > w.line));
        }
    }

    let mut report = |waivers: &mut Vec<Waiver>, idx: usize, lint: &str, msg: &str| {
        for (w, tgt) in waivers.iter_mut().zip(&target_of) {
            let applies = if w.file_scope { true } else { *tgt == Some(idx) };
            if applies && w.reason_ok && w.lints.iter().any(|l| l == lint) {
                w.used = true;
                return;
            }
        }
        findings.push(Finding {
            file: relpath.to_string(),
            line: idx + 1,
            lint: lint.to_string(),
            msg: msg.to_string(),
        });
    };

    let mut brace_depth: i64 = 0;
    let mut pending_test = false;
    let mut test_entry: Option<i64> = None;
    for (idx, (code, _)) in lines.iter().enumerate() {
        if is_test_attr(code) {
            pending_test = true;
        }
        if pending_test && test_entry.is_none() && code.contains('{') {
            test_entry = Some(brace_depth);
            pending_test = false;
        }
        let in_test = test_entry.is_some();

        if l1_on && !in_test && hit_thread(code) {
            report(
                &mut waivers,
                idx,
                "thread-spawn",
                "thread spawn/scope outside runtime/ (use runtime::pool)",
            );
        }
        if find_word(code, "unsafe") {
            let lo = idx.saturating_sub(SAFETY_WINDOW);
            if !(lo..=idx).any(|k| has_safety(&lines[k].1)) {
                report(
                    &mut waivers,
                    idx,
                    "undocumented-unsafe",
                    "unsafe without a SAFETY: comment within 5 lines above",
                );
            }
        }
        if l3_on && !in_test && (find_word(code, "HashMap") || find_word(code, "HashSet")) {
            report(
                &mut waivers,
                idx,
                "unordered-map",
                "HashMap/HashSet in a result-producing module (use BTreeMap/BTreeSet or a sorted Vec)",
            );
        }
        // deliberately NOT gated on `in_test`: a NaN-lossy comparison in
        // a test weakens the assertion it feeds just as silently
        if hit_order(code) {
            report(
                &mut waivers,
                idx,
                "non-total-order",
                "partial_cmp / f64::max / f64::min on possibly-NaN data (use total_cmp)",
            );
        }
        if l5_on && !in_test && hit_cast(code) {
            report(
                &mut waivers,
                idx,
                "unchecked-cast",
                "bare narrowing cast in header/offset decoding (use try_from or checked arithmetic)",
            );
        }
        if l6_on && !in_test && hit_panic(code) {
            report(
                &mut waivers,
                idx,
                "lib-panic",
                "unwrap/expect/panic! in library code (return an error)",
            );
        }
        if l7_on && !in_test && hit_f32(code) {
            report(
                &mut waivers,
                idx,
                "mixed-precision-confined",
                "f32 in the solver stack outside linalg/mixed.rs (the certified screening shadow is the one sanctioned low-precision path)",
            );
        }

        brace_depth += code.matches('{').count() as i64;
        brace_depth -= code.matches('}').count() as i64;
        if let Some(entry) = test_entry {
            if brace_depth <= entry {
                test_entry = None;
            }
        }
    }

    for w in &waivers {
        if !w.used && w.names_ok && w.reason_ok {
            findings.push(Finding {
                file: relpath.to_string(),
                line: w.line + 1,
                lint: "unused-waiver".to_string(),
                msg: "waiver matched no finding".to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Driver.
// ---------------------------------------------------------------------------

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map_or(false, |x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "-h" | "--help" => {
                eprintln!("usage: vet [--json] [ROOT]   (ROOT defaults to rust/src)");
                return ExitCode::from(0);
            }
            a if a.starts_with('-') => {
                eprintln!("vet: unknown flag '{a}'");
                return ExitCode::from(2);
            }
            a => {
                if root.is_some() {
                    eprintln!("vet: more than one ROOT given");
                    return ExitCode::from(2);
                }
                root = Some(PathBuf::from(a));
            }
        }
    }
    let root = root.unwrap_or_else(|| PathBuf::from("rust/src"));

    let mut files: Vec<PathBuf> = Vec::new();
    if root.is_file() {
        files.push(root.clone());
    } else if let Err(e) = collect_rs(&root, &mut files) {
        eprintln!("vet: cannot scan {}: {e}", root.display());
        return ExitCode::from(2);
    }

    let mut findings: Vec<Finding> = Vec::new();
    let mut scanned = 0usize;
    for path in &files {
        let rel = if root.is_file() {
            path.file_name().map(PathBuf::from).unwrap_or_else(|| path.clone())
        } else {
            path.strip_prefix(&root).map(PathBuf::from).unwrap_or_else(|_| path.clone())
        };
        let rel: String = rel
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let mut src = String::new();
        match fs::File::open(path).and_then(|mut f| f.read_to_string(&mut src)) {
            Ok(_) => {}
            Err(e) => {
                eprintln!("vet: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        }
        scanned += 1;
        scan_file(&rel, &src, &mut findings);
    }

    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.lint, &a.msg).cmp(&(&b.file, b.line, &b.lint, &b.msg))
    });

    if json {
        let mut out = String::from("{\"findings\":[");
        for (i, f) in findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"file\":\"{}\",\"line\":{},\"lint\":\"{}\",\"message\":\"{}\"}}",
                json_escape(&f.file),
                f.line,
                json_escape(&f.lint),
                json_escape(&f.msg)
            ));
        }
        out.push_str(&format!("],\"files_scanned\":{scanned}}}"));
        println!("{out}");
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.lint, f.msg);
        }
        eprintln!("-- {} findings over {} files", findings.len(), scanned);
    }
    if findings.is_empty() {
        ExitCode::from(0)
    } else {
        ExitCode::from(1)
    }
}
