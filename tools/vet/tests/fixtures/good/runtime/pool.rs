//! Exemptions fixture: `runtime/` is the one module allowed to spawn
//! threads, and documented `unsafe` passes everywhere.

pub fn spawn_worker() -> std::thread::JoinHandle<()> {
    std::thread::spawn(|| {})
}

pub fn read(p: *const u64) -> u64 {
    // SAFETY: the caller guarantees `p` is valid for reads and the
    // pointee outlives this call.
    unsafe { *p }
}
