//! CLI fixture: `cli/` is panic-exempt (a process boundary owns its
//! own exit), so unwrap/expect pass here without waivers.

pub fn run(args: &[String]) -> u64 {
    args.first().unwrap().parse().expect("numeric argument")
}
