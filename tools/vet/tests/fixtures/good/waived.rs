// vet: allow-file(non-total-order): this whole file post-processes
// display strings where NaN cannot occur by construction

//! Waiver fixture: line waivers on the offending line or the line
//! above, plus a file-scope waiver, all of them used.

pub fn display_max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(0.0, f64::max)
}

pub fn poison_free(m: &std::sync::Mutex<u64>) -> u64 {
    *m.lock().unwrap_or_else(|e| e.into_inner())
}

pub fn must(x: Option<u64>) -> u64 {
    // vet: allow(lib-panic): fixture exercises the line-above waiver
    x.unwrap()
}

pub fn must_too(x: Option<u64>) -> u64 {
    x.unwrap() // vet: allow(lib-panic): fixture exercises the same-line waiver
}
