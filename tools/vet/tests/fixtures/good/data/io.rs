//! Checked-cast fixture: `data/io.rs` decodes untrusted bytes, so
//! narrowing goes through `try_from` and widening through a checked
//! helper — no bare `as` casts.

pub fn decode(len_field: u64) -> Result<usize, std::num::TryFromIntError> {
    usize::try_from(len_field)
}

pub fn stringy() -> &'static str {
    // banned tokens inside strings and comments are not code: as usize
    "cast me as usize and unwrap() f64::max thread::spawn HashMap"
}
