//! Sanctuary fixture: `linalg/mixed.rs` is the one file in the solver
//! stack where `f32` is sanctioned (the certified screening shadow).

pub fn shadow_dot(x: &[f32], y: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (a, b) in x.iter().zip(y) {
        s += a * b;
    }
    s
}
