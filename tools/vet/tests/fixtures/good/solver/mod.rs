//! Result-bearing-module fixture: deterministic containers pass, and a
//! justified waiver silences a deliberate exception.

use std::collections::BTreeMap;

pub fn tally(xs: &[u64]) -> usize {
    let mut counts: BTreeMap<u64, usize> = BTreeMap::new();
    for &x in xs {
        *counts.entry(x).or_insert(0) += 1;
    }
    counts.len()
}

pub fn interned() -> usize {
    // vet: allow(unordered-map): capacity probe only — the map is
    // dropped before anything order-sensitive reads it
    let m: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();
    m.capacity()
}

pub fn largest(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(0.0, |a, b| if b.total_cmp(&a).is_gt() { b } else { a })
}

/// `MixedF32` (an identifier) and `"mixed-f32"` (a string) must never
/// trip the case-sensitive, literal-blind `f32` token search.
pub enum Fixture {
    MixedF32,
}

pub fn label() -> &'static str {
    "mixed-f32"
}

#[cfg(test)]
mod tests {
    // L7 is test-exempt: a precision probe in a test cannot corrupt a
    // result certificate
    pub fn as_single(x: f64) -> f32 {
        x as f32
    }
}
