//! Test-code fixture: panic channels inside `#[cfg(test)]` are the
//! assertion mechanism, not a lint violation.

pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles() {
        let parsed: u64 = "21".parse().unwrap();
        assert_eq!(double(parsed), 42);
        let v: Vec<u64> = Vec::new();
        assert!(v.first().is_none());
    }

    #[test]
    #[should_panic]
    fn can_panic_here() {
        panic!("tests may panic");
    }
}
