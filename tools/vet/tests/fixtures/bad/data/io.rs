//! L5 fixture: bare `as` casts in an untrusted-input decode path
//! (`data/io.rs` is one of the two files the lint covers).

pub fn decode(len_field: u64) -> usize {
    len_field as usize
}

pub fn encode(n: usize) -> u64 {
    n as u64
}
