//! L7 fixture: f32 leaking into the solver stack (`saif/`).

pub fn score(x: &[f64]) -> f64 {
    let s: f32 = x.iter().map(|&v| v as f32).sum();
    s as f64
}

pub fn half() -> f64 {
    (0.5f32) as f64
}
