//! L6 fixture: panic channels in library (non-test, non-CLI) code.

pub fn first(xs: &[u64]) -> u64 {
    *xs.first().unwrap()
}

pub fn parse(s: &str) -> u64 {
    s.parse().expect("a number")
}

pub fn forbid(flag: bool) {
    if flag {
        panic!("flag must be false");
    }
}
