//! L1 fixture: raw thread spawns outside `runtime/`.

pub fn fan_out() {
    let h = std::thread::spawn(|| 1 + 1);
    let _ = h.join();
}

pub fn scoped_fan_out(xs: &mut [u64]) {
    std::thread::scope(|s| {
        for x in xs.iter_mut() {
            s.spawn(move || *x += 1);
        }
    });
}

pub fn named() {
    let _ = std::thread::Builder::new().name("rogue".into());
}
