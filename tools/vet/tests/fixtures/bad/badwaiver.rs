//! Waiver-machinery fixture: unknown lint names, empty reasons, and
//! waivers that match nothing are themselves findings.

// vet: allow(made-up-lint): the lint name does not exist
pub fn a() {}

// vet: allow(lib-panic):
pub fn empty_reason() {}

// vet: allow(lib-panic): nothing on the next code line panics
pub fn unused() -> u64 {
    42
}
