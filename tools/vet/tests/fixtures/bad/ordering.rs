//! L4 fixture: NaN-lossy float comparisons.

pub fn worst(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(0.0, f64::max)
}

pub fn best(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn sorted(mut xs: Vec<f64>) -> Vec<f64> {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    xs
}

#[cfg(test)]
mod tests {
    // L4 is NOT test-exempt: a lossy fold here silently weakens the
    // assertion it feeds.
    pub fn worst_in_test(xs: &[f64]) -> f64 {
        xs.iter().cloned().fold(0.0, f64::max)
    }
}
