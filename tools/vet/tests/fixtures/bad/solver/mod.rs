//! L3 fixture: iteration-order-sensitive maps inside a result-bearing
//! module (`solver/`).

use std::collections::HashMap;
use std::collections::HashSet;

pub fn tally(xs: &[u64]) -> usize {
    let mut seen = HashSet::new();
    for &x in xs {
        seen.insert(x);
    }
    let mut counts: HashMap<u64, usize> = HashMap::new();
    for &x in xs {
        *counts.entry(x).or_insert(0) += 1;
    }
    seen.len() + counts.len()
}
