//! L2 fixture: `unsafe` with no SAFETY comment in reach.

pub fn peek(p: *const u64) -> u64 {
    // this comment is not a safety argument
    unsafe { *p }
}

// A SAFETY comment that is too far away (> 5 lines) does not count.
// SAFETY: stale, distant, and wrong.
//
//
//
//
//
pub fn poke(p: *mut u64) {
    unsafe {
        *p = 7;
    }
}
