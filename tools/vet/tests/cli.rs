//! End-to-end checks of the `vet` binary over the fixture corpora in
//! `tests/fixtures/`: the `bad/` tree must produce exactly the
//! expected findings (one per seeded violation, nothing else), the
//! `good/` tree must be clean, and the JSON/exit-code surface must
//! hold — that is the contract CI scripts depend on.

use std::path::PathBuf;
use std::process::Command;

fn fixture(name: &str) -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join(name)
        .to_str()
        .expect("utf-8 fixture path")
        .to_string()
}

fn vet(args: &[&str]) -> (i32, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_vet"))
        .args(args)
        .output()
        .expect("run vet binary");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// (file, line, lint) for every violation seeded into `bad/`. Keeping
/// the list exhaustive cuts both ways: a lint that stops firing fails
/// the test, and so does a matcher that starts over-firing.
const EXPECTED_BAD: &[(&str, u32, &str)] = &[
    ("badwaiver.rs", 4, "bad-waiver"),
    ("badwaiver.rs", 7, "bad-waiver"),
    ("badwaiver.rs", 10, "unused-waiver"),
    ("data/io.rs", 5, "unchecked-cast"),
    ("data/io.rs", 9, "unchecked-cast"),
    ("nodoc.rs", 5, "undocumented-unsafe"),
    ("nodoc.rs", 16, "undocumented-unsafe"),
    ("ordering.rs", 4, "non-total-order"),
    ("ordering.rs", 8, "non-total-order"),
    ("ordering.rs", 12, "non-total-order"),
    // inside #[cfg(test)] — L4 is the one lint with no test exemption
    ("ordering.rs", 21, "non-total-order"),
    ("panics.rs", 4, "lib-panic"),
    ("panics.rs", 8, "lib-panic"),
    ("panics.rs", 13, "lib-panic"),
    // f32 as a type ascription + cast, and as a literal suffix
    ("saif/scan.rs", 4, "mixed-precision-confined"),
    ("saif/scan.rs", 9, "mixed-precision-confined"),
    ("solver/mod.rs", 4, "unordered-map"),
    ("solver/mod.rs", 5, "unordered-map"),
    ("solver/mod.rs", 8, "unordered-map"),
    ("solver/mod.rs", 12, "unordered-map"),
    ("spawny.rs", 4, "thread-spawn"),
    ("spawny.rs", 9, "thread-spawn"),
    ("spawny.rs", 17, "thread-spawn"),
];

#[test]
fn bad_tree_yields_exactly_the_seeded_findings() {
    let (code, stdout, _) = vet(&[&fixture("bad")]);
    assert_eq!(code, 1, "findings must exit 1:\n{stdout}");
    let lines: Vec<&str> = stdout.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(
        lines.len(),
        EXPECTED_BAD.len(),
        "finding count drifted:\n{stdout}"
    );
    for &(file, line, lint) in EXPECTED_BAD {
        let needle = format!("{file}:{line}: [{lint}]");
        assert!(
            stdout.contains(&needle),
            "missing expected finding '{needle}':\n{stdout}"
        );
    }
}

#[test]
fn good_tree_is_clean() {
    let (code, stdout, stderr) = vet(&[&fixture("good")]);
    assert_eq!(code, 0, "clean tree must exit 0:\n{stdout}\n{stderr}");
    assert!(stdout.trim().is_empty(), "no findings expected:\n{stdout}");
    assert!(stderr.contains("0 findings"), "summary on stderr:\n{stderr}");
}

#[test]
fn json_output_is_machine_readable() {
    let (code, stdout, _) = vet(&["--json", &fixture("bad")]);
    assert_eq!(code, 1);
    assert!(stdout.starts_with("{\"findings\":["), "{stdout}");
    assert!(stdout.contains("\"files_scanned\":8"), "{stdout}");
    assert!(
        stdout.contains("\"lint\":\"thread-spawn\""),
        "lint field present: {stdout}"
    );
    // clean tree: well-formed empty array, still exit 0
    let (code, stdout, _) = vet(&["--json", &fixture("good")]);
    assert_eq!(code, 0);
    assert!(stdout.starts_with("{\"findings\":[]"), "{stdout}");
}

#[test]
fn single_file_root_is_supported() {
    let (code, stdout, _) = vet(&[&fixture("bad/panics.rs")]);
    assert_eq!(code, 1);
    // relpath of a file root is its file name
    assert!(stdout.contains("panics.rs:4: [lib-panic]"), "{stdout}");
    assert_eq!(stdout.lines().filter(|l| !l.is_empty()).count(), 3, "{stdout}");
}

#[test]
fn missing_root_is_a_usage_error() {
    let (code, _, stderr) = vet(&[&fixture("does-not-exist")]);
    assert_eq!(code, 2, "IO/usage errors exit 2: {stderr}");
    assert!(!stderr.is_empty());
}

#[test]
fn scope_exemptions_hold_only_in_their_modules() {
    // the same spawn that passes under good/runtime/ fails at top level
    let (code, stdout, _) = vet(&[&fixture("good/runtime")]);
    assert_eq!(code, 1, "runtime/ exemption is per-tree-root:\n{stdout}");
    assert!(stdout.contains("[thread-spawn]"), "{stdout}");
}
