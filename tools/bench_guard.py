#!/usr/bin/env python3
"""Bench-regression guard for BENCH_kernels.json / BENCH_methods.json /
BENCH_serve.json (std-lib only).

Usage: bench_guard.py [--require-real-baseline] <baseline.json> <fresh.json>

Compares a freshly regenerated bench record against the committed
baseline and exits non-zero when any guarded timing regressed by more
than the tolerance (default 25%; override with BENCH_TOLERANCE, e.g.
BENCH_TOLERANCE=0.5 for noisy machines). Kernel records guard every
numeric `*_us` field except the ooc rows — schema-derived, so the
blocked-kernel and f32-scan rows (`*_blocked_us`, `*_f32_scan_us`)
are guarded the moment the baseline carries real numbers, and a new
kernel row never needs a guard-side edit; method-shootout records (marker
"bench":"methods") guard every numeric `*_secs` row except the ooc
scenarios and the `*_curve_secs` arrays — the schema is derived from
the records themselves, so new scenario/method rows are guarded the
moment the baseline carries real numbers for them. Serving records
(marker "bench":"serve") guard every numeric `*_us` field
(lower-better latency percentiles) and every `*_rps` field
(higher-better throughput — a regression is the fresh value dropping
below baseline by more than the tolerance).

Null baselines (the pre-toolchain placeholder) and missing fields are
skipped with a LOUD note — the guard only ever compares real numbers
to real numbers, so the first CI run that lands real numbers
establishes the baseline instead of failing against the placeholder.
A placeholder pass is therefore NOT evidence of performance parity;
the scheduled CI job passes --require-real-baseline, which turns the
silent pass into a failure so a never-populated baseline cannot rot
unnoticed forever.
"""

import json
import os
import sys

def kernel_fields(baseline, fresh):
    """Guarded field list for a kernel record: every numeric `*_us` key
    present in either record (scan/epoch/blocked/f32-scan hot-path
    timings, microseconds, lower is better), minus the ooc rows — disk
    timings on shared CI runners are too noisy to gate on. Schema-
    derived like the methods/serve modes, so the blocked-kernel and
    f32-scan rows are guarded without a field list to keep in sync."""
    keys = set()
    for rec in (baseline, fresh):
        if not isinstance(rec, dict):
            continue
        keys.update(
            k
            for k, v in rec.items()
            if k.endswith("_us")
            and "ooc" not in k
            and isinstance(v, (int, float))
            and not isinstance(v, bool)
        )
    return sorted(keys)


def is_methods_record(rec):
    return isinstance(rec, dict) and rec.get("bench") == "methods"


def is_serve_record(rec):
    return isinstance(rec, dict) and rec.get("bench") == "serve"


def serve_fields(baseline, fresh):
    """Guarded (field, direction) list for a serving record: every
    numeric `*_us` key is lower-better latency, every `*_rps` key is
    higher-better throughput. Schema-derived like the methods mode, so
    new fields are guarded once the baseline carries real numbers."""
    lower, higher = set(), set()
    for rec in (baseline, fresh):
        if not isinstance(rec, dict):
            continue
        for k, v in rec.items():
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                continue
            if k.endswith("_us"):
                lower.add(k)
            elif k.endswith("_rps"):
                higher.add(k)
    return [(k, "lower") for k in sorted(lower)] + [(k, "higher") for k in sorted(higher)]


def methods_fields(baseline, fresh):
    """Guarded field list for a method-shootout record: every numeric
    `*_secs` key present in either record, minus the ooc scenarios
    (disk timings on shared runners are too noisy to gate on) and the
    `*_curve_secs` time-to-gap arrays (shape data, not a scalar to
    gate)."""
    keys = set()
    for rec in (baseline, fresh):
        if not isinstance(rec, dict):
            continue
        keys.update(
            k
            for k, v in rec.items()
            if k.endswith("_secs")
            and "ooc" not in k
            and not k.endswith("_curve_secs")
            and isinstance(v, (int, float))
            and not isinstance(v, bool)
        )
    return sorted(keys)


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench guard: cannot read {path}: {e}", file=sys.stderr)
        return None


def placeholder_warning(reason, require_real):
    """One loud, grep-able block on stderr whenever no real comparison
    happened. Under --require-real-baseline it is fatal."""
    print(
        "=" * 72 + "\n"
        "bench guard: WARNING: NO REAL BASELINE COMPARISON WAS PERFORMED\n"
        f"bench guard: reason: {reason}\n"
        "bench guard: this pass says NOTHING about performance. Regenerate\n"
        "bench guard: the baseline (tools/ci.sh bench) on a quiet machine\n"
        "bench guard: and commit BENCH_kernels.json to arm the guard.\n"
        + "=" * 72,
        file=sys.stderr,
    )
    if require_real:
        print(
            "bench guard: --require-real-baseline set: failing instead of "
            "passing vacuously",
            file=sys.stderr,
        )
        return 1
    return 0


def main():
    argv = sys.argv[1:]
    require_real = "--require-real-baseline" in argv
    argv = [a for a in argv if a != "--require-real-baseline"]
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline_path, fresh_path = argv[0], argv[1]
    try:
        tol = float(os.environ.get("BENCH_TOLERANCE", "0.25"))
    except ValueError:
        print("bench guard: bad BENCH_TOLERANCE", file=sys.stderr)
        return 2

    baseline = load(baseline_path)
    fresh = load(fresh_path)
    if baseline is None:
        return placeholder_warning("no readable baseline (first run?)", require_real)
    if fresh is None:
        print("bench guard: fresh record unreadable — did the bench run?", file=sys.stderr)
        return 1

    if is_serve_record(baseline) or is_serve_record(fresh):
        fields = serve_fields(baseline, fresh)
        if not fields:
            return placeholder_warning(
                "serve record carries no numeric *_us/*_rps rows (placeholder baseline)",
                require_real,
            )
    elif is_methods_record(baseline) or is_methods_record(fresh):
        fields = [(f, "lower") for f in methods_fields(baseline, fresh)]
        if not fields:
            return placeholder_warning(
                "methods record carries no numeric *_secs rows (placeholder baseline)",
                require_real,
            )
    else:
        fields = [(f, "lower") for f in kernel_fields(baseline, fresh)]
        if not fields:
            return placeholder_warning(
                "kernel record carries no numeric *_us rows (placeholder baseline)",
                require_real,
            )

    regressions, compared, skipped = [], 0, []
    for field, direction in fields:
        base, new = baseline.get(field), fresh.get(field)
        if not isinstance(base, (int, float)) or not isinstance(new, (int, float)):
            skipped.append(field)
            continue
        if base <= 0:
            skipped.append(field)
            continue
        compared += 1
        ratio = new / base
        marker = ""
        regressed = ratio > 1.0 + tol if direction == "lower" else ratio < 1.0 - tol
        if regressed:
            regressions.append((field, base, new, ratio))
            marker = "  <-- REGRESSION"
        print(f"  {field:28s} {base:12.2f} -> {new:12.2f}  ({ratio:5.2f}x){marker}")

    if skipped:
        print(f"bench guard: skipped (no numeric baseline): {', '.join(skipped)}")
    if compared == 0:
        return placeholder_warning(
            "all guarded fields are null/missing (placeholder baseline)", require_real
        )
    if regressions:
        print(
            f"bench guard: {len(regressions)} guarded row(s) regressed more than "
            f"{tol:.0%} (override with BENCH_TOLERANCE):",
            file=sys.stderr,
        )
        for field, base, new, ratio in regressions:
            print(f"  {field}: {base:.2f} -> {new:.2f} ({ratio:.2f}x)", file=sys.stderr)
        return 1
    print(f"bench guard: {compared} guarded rows within {tol:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
