#!/usr/bin/env python3
"""Bench-regression guard for BENCH_kernels.json (std-lib only).

Usage: bench_guard.py [--require-real-baseline] <baseline.json> <fresh.json>

Compares the freshly regenerated kernel-bench record against the
committed baseline and exits non-zero when any guarded scan/epoch
timing regressed by more than the tolerance (default 25%; override
with BENCH_TOLERANCE, e.g. BENCH_TOLERANCE=0.5 for noisy machines).

Null baselines (the pre-toolchain placeholder) and missing fields are
skipped with a LOUD note — the guard only ever compares real numbers
to real numbers, so the first CI run that lands real numbers
establishes the baseline instead of failing against the placeholder.
A placeholder pass is therefore NOT evidence of performance parity;
the scheduled CI job passes --require-real-baseline, which turns the
silent pass into a failure so a never-populated baseline cannot rot
unnoticed forever.
"""

import json
import os
import sys

# Guarded rows: the scan + epoch hot-path timings (microseconds, lower
# is better). The ooc rows are excluded on purpose — disk timings on
# shared CI runners are too noisy to gate on.
GUARDED_US_FIELDS = [
    "dense_serial_us",
    "dense_parallel_us",
    "dense_pooled_us",
    "sparse1pct_serial_us",
    "sparse1pct_parallel_us",
    "sparse1pct_pooled_us",
    "epoch_serial_us",
    "epoch_sharded_us",
    "epoch_pooled_us",
]


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench guard: cannot read {path}: {e}", file=sys.stderr)
        return None


def placeholder_warning(reason, require_real):
    """One loud, grep-able block on stderr whenever no real comparison
    happened. Under --require-real-baseline it is fatal."""
    print(
        "=" * 72 + "\n"
        "bench guard: WARNING: NO REAL BASELINE COMPARISON WAS PERFORMED\n"
        f"bench guard: reason: {reason}\n"
        "bench guard: this pass says NOTHING about performance. Regenerate\n"
        "bench guard: the baseline (tools/ci.sh bench) on a quiet machine\n"
        "bench guard: and commit BENCH_kernels.json to arm the guard.\n"
        + "=" * 72,
        file=sys.stderr,
    )
    if require_real:
        print(
            "bench guard: --require-real-baseline set: failing instead of "
            "passing vacuously",
            file=sys.stderr,
        )
        return 1
    return 0


def main():
    argv = sys.argv[1:]
    require_real = "--require-real-baseline" in argv
    argv = [a for a in argv if a != "--require-real-baseline"]
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline_path, fresh_path = argv[0], argv[1]
    try:
        tol = float(os.environ.get("BENCH_TOLERANCE", "0.25"))
    except ValueError:
        print("bench guard: bad BENCH_TOLERANCE", file=sys.stderr)
        return 2

    baseline = load(baseline_path)
    fresh = load(fresh_path)
    if baseline is None:
        return placeholder_warning("no readable baseline (first run?)", require_real)
    if fresh is None:
        print("bench guard: fresh record unreadable — did the bench run?", file=sys.stderr)
        return 1

    regressions, compared, skipped = [], 0, []
    for field in GUARDED_US_FIELDS:
        base, new = baseline.get(field), fresh.get(field)
        if not isinstance(base, (int, float)) or not isinstance(new, (int, float)):
            skipped.append(field)
            continue
        if base <= 0:
            skipped.append(field)
            continue
        compared += 1
        ratio = new / base
        marker = ""
        if ratio > 1.0 + tol:
            regressions.append((field, base, new, ratio))
            marker = "  <-- REGRESSION"
        print(f"  {field:28s} {base:12.2f} -> {new:12.2f}  ({ratio:5.2f}x){marker}")

    if skipped:
        print(f"bench guard: skipped (no numeric baseline): {', '.join(skipped)}")
    if compared == 0:
        return placeholder_warning(
            "all guarded fields are null/missing (placeholder baseline)", require_real
        )
    if regressions:
        print(
            f"bench guard: {len(regressions)} guarded row(s) regressed more than "
            f"{tol:.0%} (override with BENCH_TOLERANCE):",
            file=sys.stderr,
        )
        for field, base, new, ratio in regressions:
            print(f"  {field}: {base:.2f}us -> {new:.2f}us ({ratio:.2f}x)", file=sys.stderr)
        return 1
    print(f"bench guard: {compared} guarded rows within {tol:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
