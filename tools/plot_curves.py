#!/usr/bin/env python3
"""Render per-scenario time-to-ε SVG plots from BENCH_methods.json
(std-lib only — no matplotlib in the CI image, and none needed).

Usage: plot_curves.py [<BENCH_methods.json> [<output-dir>]]
       (defaults: ./BENCH_methods.json, ./out/curves)

The method shootout records a time-to-gap curve per (scenario, method):
`<scenario>_<method>_curve_secs` is the cumulative wall time at each
λ-grid point and `<scenario>_<method>_curve_gap` the certified duality
gap reached there. This tool groups the curves by scenario and writes
one `<scenario>.svg` per scenario with a log-log polyline per method —
the shape that makes "safe screening pays for itself by the time the
gap certifies" visible at a glance.

This is an *advisory* artifact: a placeholder record (the committed
pre-toolchain baseline carries no curves) exits 0 with a loud note, so
CI can run it unconditionally and upload whatever came out.
"""

import json
import math
import os
import sys

# The shootout names scenarios `<loss>_<backend>` (ls_dense,
# logit_sparse, ...) — two underscore-separated tokens, always, so a
# record key splits unambiguously even when method labels themselves
# contain underscores.
SCENARIO_TOKENS = 2

WIDTH, HEIGHT = 640, 420
MARGIN_L, MARGIN_R, MARGIN_T, MARGIN_B = 64, 160, 36, 48
COLORS = [
    "#1f77b4", "#d62728", "#2ca02c", "#9467bd",
    "#ff7f0e", "#8c564b", "#17becf", "#7f7f7f",
]


def curves_by_scenario(rec):
    """{scenario: [(method, [secs...], [gap...])]} from a shootout
    record; curves with non-numeric or mismatched arrays are dropped."""
    out = {}
    for key, secs in rec.items():
        if not key.endswith("_curve_secs") or not isinstance(secs, list):
            continue
        stem = key[: -len("_curve_secs")]
        gaps = rec.get(stem + "_curve_gap")
        if not isinstance(gaps, list) or len(gaps) != len(secs) or not secs:
            continue
        try:
            pts = [(float(s), float(g)) for s, g in zip(secs, gaps)]
        except (TypeError, ValueError):
            continue
        parts = stem.split("_")
        if len(parts) <= SCENARIO_TOKENS:
            continue
        scenario = "_".join(parts[:SCENARIO_TOKENS])
        method = "_".join(parts[SCENARIO_TOKENS:])
        out.setdefault(scenario, []).append((method, pts))
    return out


def log_span(values, floor):
    """(lo, hi) log10 bounds with a little headroom; degenerate spans
    are widened so the projection below never divides by zero."""
    vals = [max(v, floor) for v in values]
    lo, hi = math.log10(min(vals)), math.log10(max(vals))
    if hi - lo < 1e-9:
        lo, hi = lo - 0.5, hi + 0.5
    pad = 0.05 * (hi - lo)
    return lo - pad, hi + pad


def svg_for(scenario, methods, eps):
    xs = [s for _, pts in methods for s, _ in pts]
    ys = [g for _, pts in methods for _, g in pts]
    x_lo, x_hi = log_span(xs, 1e-6)
    y_lo, y_hi = log_span(ys + ([eps] if eps else []), 1e-14)
    plot_w = WIDTH - MARGIN_L - MARGIN_R
    plot_h = HEIGHT - MARGIN_T - MARGIN_B

    def px(secs):
        return MARGIN_L + plot_w * (math.log10(max(secs, 1e-6)) - x_lo) / (x_hi - x_lo)

    def py(gap):
        return MARGIN_T + plot_h * (y_hi - math.log10(max(gap, 1e-14))) / (y_hi - y_lo)

    parts = [
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{WIDTH}" height="{HEIGHT}" '
        f'viewBox="0 0 {WIDTH} {HEIGHT}" font-family="monospace" font-size="11">',
        f'<rect width="{WIDTH}" height="{HEIGHT}" fill="white"/>',
        f'<rect x="{MARGIN_L}" y="{MARGIN_T}" width="{plot_w}" height="{plot_h}" '
        'fill="none" stroke="#cccccc"/>',
        f'<text x="{MARGIN_L}" y="{MARGIN_T - 12}" font-size="13">'
        f"{scenario}: certified gap vs cumulative seconds (log-log)</text>",
        f'<text x="{MARGIN_L + plot_w / 2:.0f}" y="{HEIGHT - 12}" '
        'text-anchor="middle">cumulative seconds</text>',
    ]
    # decade gridlines + tick labels on both axes
    for d in range(math.ceil(x_lo), math.floor(x_hi) + 1):
        x = px(10.0 ** d)
        parts.append(
            f'<line x1="{x:.1f}" y1="{MARGIN_T}" x2="{x:.1f}" '
            f'y2="{MARGIN_T + plot_h}" stroke="#eeeeee"/>'
            f'<text x="{x:.1f}" y="{MARGIN_T + plot_h + 16}" '
            f'text-anchor="middle">1e{d}</text>'
        )
    for d in range(math.ceil(y_lo), math.floor(y_hi) + 1):
        y = py(10.0 ** d)
        parts.append(
            f'<line x1="{MARGIN_L}" y1="{y:.1f}" x2="{MARGIN_L + plot_w}" '
            f'y2="{y:.1f}" stroke="#eeeeee"/>'
            f'<text x="{MARGIN_L - 6}" y="{y + 4:.1f}" text-anchor="end">1e{d}</text>'
        )
    if eps:
        y = py(eps)
        parts.append(
            f'<line x1="{MARGIN_L}" y1="{y:.1f}" x2="{MARGIN_L + plot_w}" y2="{y:.1f}" '
            'stroke="#999999" stroke-dasharray="6,4"/>'
            f'<text x="{MARGIN_L + plot_w + 6}" y="{y + 4:.1f}" fill="#666666">ε</text>'
        )
    for i, (method, pts) in enumerate(sorted(methods)):
        color = COLORS[i % len(COLORS)]
        path = " ".join(f"{px(s):.1f},{py(g):.1f}" for s, g in pts)
        parts.append(
            f'<polyline points="{path}" fill="none" stroke="{color}" stroke-width="1.6"/>'
        )
        for s, g in pts:
            parts.append(
                f'<circle cx="{px(s):.1f}" cy="{py(g):.1f}" r="2.4" fill="{color}"/>'
            )
        ly = MARGIN_T + 14 + 16 * i
        parts.append(
            f'<line x1="{MARGIN_L + plot_w + 8}" y1="{ly - 4}" '
            f'x2="{MARGIN_L + plot_w + 28}" y2="{ly - 4}" stroke="{color}" stroke-width="2"/>'
            f'<text x="{MARGIN_L + plot_w + 34}" y="{ly}">{method}</text>'
        )
    parts.append("</svg>")
    return "\n".join(parts) + "\n"


def main():
    argv = sys.argv[1:]
    if len(argv) > 2 or "-h" in argv or "--help" in argv:
        print(__doc__, file=sys.stderr)
        return 2
    rec_path = argv[0] if argv else "BENCH_methods.json"
    out_dir = argv[1] if len(argv) > 1 else os.path.join("out", "curves")
    try:
        with open(rec_path) as f:
            rec = json.load(f)
    except (OSError, ValueError) as e:
        print(f"plot curves: cannot read {rec_path}: {e}", file=sys.stderr)
        return 1
    if not isinstance(rec, dict) or rec.get("bench") != "methods":
        print(f"plot curves: {rec_path} is not a method-shootout record", file=sys.stderr)
        return 1
    scenarios = curves_by_scenario(rec)
    if not scenarios:
        print(
            "plot curves: NOTE: record carries no time-to-gap curves "
            "(placeholder baseline — regenerate with `cargo bench --bench "
            "methods`); nothing to plot, exiting 0",
            file=sys.stderr,
        )
        return 0
    eps = rec.get("eps")
    eps = float(eps) if isinstance(eps, (int, float)) and not isinstance(eps, bool) else None
    os.makedirs(out_dir, exist_ok=True)
    for scenario, methods in sorted(scenarios.items()):
        path = os.path.join(out_dir, f"{scenario}.svg")
        with open(path, "w") as f:
            f.write(svg_for(scenario, methods, eps))
        print(f"plot curves: wrote {path} ({len(methods)} methods)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
