"""AOT pipeline checks: manifest consistency, HLO text validity."""

import json
import os

import pytest

from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lowering_produces_parseable_hlo_text():
    lowered = aot.lower_cm("cm_ls", 128, 64)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[128,64]" in text  # the X parameter at the bucket shape
    lowered = aot.lower_scores(128, 128)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text


def test_vmem_report_mentions_sizes():
    r = aot.vmem_report("cm_ls", 512, 1024)
    assert "VMEM" in r and "n=512" in r
    r = aot.vmem_report("scores", 128, 5120)
    assert "BW-bound" in r


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_matches_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    assert m["k_epochs"] == aot.K_EPOCHS
    assert len(m["artifacts"]) == (
        len(aot.CM_LS_BUCKETS) + len(aot.CM_LOG_BUCKETS) + len(aot.SCORES_BUCKETS)
    )
    for a in m["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        assert os.path.getsize(path) > 100
        # io shapes advertised to rust match the bucket dims
        if a["kind"] == "scores":
            assert a["inputs"][0][1] == [a["n"], a["p"]]
            assert a["outputs"][0][1] == [a["p"]]
        else:
            assert a["inputs"][0][1] == [a["n"], a["p"]]
            assert a["outputs"][4][1] == [a["n"]]  # theta
