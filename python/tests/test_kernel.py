"""Kernel-vs-reference correctness sweeps (the L1 correctness signal).

Hypothesis sweeps randomize shapes, seeds, lambda, masking and sample
padding; every case asserts the Pallas kernel (interpret=True) matches
the plain-numpy oracle in ref.py to f32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cm_epochs_ls, cm_epochs_logistic, scores
from compile.kernels import ref

SET = settings(max_examples=25, deadline=None)


def _problem(seed, n, p, logistic=False):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p)).astype(np.float32)
    w = np.ones(n, np.float32)
    npad = rng.integers(0, max(n // 4, 1))
    if npad:
        X[n - npad:] = 0.0
        w[n - npad:] = 0.0
    if logistic:
        y = np.where(rng.random(n) > 0.5, 1.0, -1.0).astype(np.float32)
        y[w == 0] = 0.0
    else:
        y = rng.normal(size=n).astype(np.float32)
        y[w == 0] = 0.0
    beta = (rng.normal(size=p) * 0.2).astype(np.float32)
    mask = (rng.random(p) > 0.25).astype(np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    # a zero-norm column now and then
    if p > 2 and rng.random() > 0.5:
        X[:, p // 2] = 0.0
    return X, y, w, beta, mask


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(2, 40),
       p=st.integers(1, 24), k=st.integers(1, 4),
       lam=st.floats(1e-3, 5.0))
def test_cm_ls_matches_ref(seed, n, p, k, lam):
    X, y, w, beta, mask = _problem(seed, n, p)
    bk, rk = cm_epochs_ls(X, y, w, beta, mask, np.float32(lam), k=k)
    bn, rn = ref.cm_epochs_ls_np(X, y, w, beta, mask, lam, k)
    np.testing.assert_allclose(np.array(bk), bn, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.array(rk), rn, atol=5e-4, rtol=2e-3)


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(2, 40),
       p=st.integers(1, 24), k=st.integers(1, 4),
       lam=st.floats(1e-4, 0.5))
def test_cm_logistic_matches_ref(seed, n, p, k, lam):
    X, y, w, beta, mask = _problem(seed, n, p, logistic=True)
    bk, uk = cm_epochs_logistic(X, y, w, beta, mask, np.float32(lam), k=k)
    bn, un = ref.cm_epochs_logistic_np(X, y, w, beta, mask, lam, k)
    np.testing.assert_allclose(np.array(bk), bn, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.array(uk), un, atol=5e-4, rtol=2e-3)


@SET
@given(seed=st.integers(0, 10_000), n=st.integers(1, 64),
       p=st.integers(1, 300))
def test_scores_matches_ref(seed, n, p):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p)).astype(np.float32)
    th = rng.normal(size=n).astype(np.float32)
    sk, n2k = scores(X, th)
    sn, n2n = ref.scores_np(X, th)
    np.testing.assert_allclose(np.array(sk), sn, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.array(n2k), n2n, atol=1e-4, rtol=1e-4)


def test_scores_tiled_block_path():
    """p divisible by BLOCK_P exercises the multi-block grid path."""
    rng = np.random.default_rng(7)
    X = rng.normal(size=(32, 1024)).astype(np.float32)
    th = rng.normal(size=32).astype(np.float32)
    sk, n2k = scores(X, th)
    sn, n2n = ref.scores_np(X, th)
    np.testing.assert_allclose(np.array(sk), sn, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.array(n2k), n2n, atol=1e-4, rtol=1e-4)


def test_cm_ls_masked_columns_stay_zero():
    X, y, w, beta, mask = _problem(3, 20, 10)
    mask[:] = 0.0
    mask[2] = 1.0
    bk, _ = cm_epochs_ls(X, y, w, beta, mask, np.float32(0.1), k=3)
    bk = np.array(bk)
    assert np.all(bk[mask == 0.0] == 0.0)


def test_cm_ls_descends_objective():
    """CM epochs never increase the LASSO objective."""
    rng = np.random.default_rng(11)
    n, p, lam = 30, 12, 0.3
    X = rng.normal(size=(n, p)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    w = np.ones(n, np.float32)
    mask = np.ones(p, np.float32)
    beta = np.zeros(p, np.float32)

    def obj(b):
        r = y - X @ b
        return 0.5 * float(r @ r) + lam * float(np.abs(b).sum())

    prev = obj(beta)
    for _ in range(5):
        beta, _ = cm_epochs_ls(X, y, w, beta, mask, np.float32(lam), k=1)
        beta = np.array(beta)
        cur = obj(beta)
        assert cur <= prev + 1e-4
        prev = cur
