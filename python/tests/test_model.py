"""L2 model-level invariants: gap sanity, dual feasibility, convergence."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SET = settings(max_examples=20, deadline=None)


def _ls_problem(seed, n=24, p=12):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    w = np.ones(n, np.float32)
    mask = np.ones(p, np.float32)
    beta = np.zeros(p, np.float32)
    return X, y, w, beta, mask


@SET
@given(seed=st.integers(0, 5000), lam=st.floats(0.05, 3.0))
def test_ls_gap_nonnegative_and_theta_feasible(seed, lam):
    X, y, w, beta, mask = _ls_problem(seed)
    out = model.cm_eval_ls(X, y, w, beta, mask, np.float32(lam), k=5)
    beta1, primal, dual, gap, theta, sc = [np.array(o) for o in out]
    assert gap >= 0.0
    assert primal >= dual - 1e-4
    # theta is feasible for the active block: |x_i^T theta| <= 1 (+eps)
    corr = np.abs(X.T @ theta)
    assert corr.max() <= 1.0 + 1e-4
    # scores output is exactly |X^T theta|
    np.testing.assert_allclose(sc, corr, atol=1e-5, rtol=1e-4)


@SET
@given(seed=st.integers(0, 5000), lam=st.floats(0.01, 0.3))
def test_logistic_gap_nonnegative_and_feasible(seed, lam):
    rng = np.random.default_rng(seed)
    n, p = 24, 10
    X = rng.normal(size=(n, p)).astype(np.float32)
    y = np.where(rng.random(n) > 0.5, 1.0, -1.0).astype(np.float32)
    w = np.ones(n, np.float32)
    mask = np.ones(p, np.float32)
    beta = np.zeros(p, np.float32)
    out = model.cm_eval_logistic(X, y, w, beta, mask, np.float32(lam), k=5)
    beta1, primal, dual, gap, theta, sc = [np.array(o) for o in out]
    assert gap >= 0.0
    corr = np.abs(X.T @ theta)
    assert corr.max() <= 1.0 + 1e-4
    s = lam * theta * y
    assert s.min() >= -1e-6 and s.max() <= 1.0 + 1e-6


def test_ls_gap_shrinks_with_iterations():
    X, y, w, beta, mask = _ls_problem(42, n=40, p=16)
    lam = np.float32(0.5)
    gaps = []
    b = beta
    for _ in range(6):
        out = model.cm_eval_ls(X, y, w, b, mask, lam, k=10)
        b = np.array(out[0])
        gaps.append(float(out[3]))
    assert gaps[-1] < gaps[0] * 0.5
    assert gaps[-1] < 1e-3 * max(gaps[0], 1.0) or gaps[-1] < 1e-4


def test_ls_converges_to_kkt():
    """At (near-)optimum the KKT conditions hold on the full block."""
    X, y, w, beta, mask = _ls_problem(7, n=30, p=10)
    lam = np.float32(1.0)
    b = beta
    for _ in range(200):
        out = model.cm_eval_ls(X, y, w, b, mask, lam, k=10)
        b = np.array(out[0])
        if float(out[3]) < 1e-9:
            break
    r = y - X @ b
    g = X.T @ r
    for i in range(len(b)):
        if b[i] != 0.0:
            assert abs(g[i] - np.sign(b[i]) * lam) < 1e-2
        else:
            assert abs(g[i]) <= lam + 1e-2


def test_padded_rows_do_not_change_answer():
    """Zero-padding samples (w=0, zero rows) must not perturb results."""
    X, y, w, beta, mask = _ls_problem(3, n=20, p=8)
    lam = np.float32(0.4)
    out1 = model.cm_eval_ls(X, y, w, beta, mask, lam, k=8)
    Xp = np.vstack([X, np.zeros((12, 8), np.float32)])
    yp = np.concatenate([y, np.zeros(12, np.float32)])
    wp = np.concatenate([w, np.zeros(12, np.float32)])
    out2 = model.cm_eval_ls(Xp, yp, wp, beta, mask, lam, k=8)
    np.testing.assert_allclose(np.array(out1[0]), np.array(out2[0]),
                               atol=1e-5, rtol=1e-4)
    for i in (1, 2, 3):
        np.testing.assert_allclose(float(out1[i]), float(out2[i]),
                                   atol=1e-3, rtol=1e-4)


def test_masked_columns_equivalent_to_submatrix():
    """Masking columns == solving the sub-problem on the kept columns."""
    X, y, w, beta, mask = _ls_problem(9, n=24, p=12)
    keep = np.array([0, 2, 5, 7, 8])
    mask = np.zeros(12, np.float32)
    mask[keep] = 1.0
    lam = np.float32(0.3)
    out_full = model.cm_eval_ls(X, y, w, beta, mask, lam, k=8)
    Xs = X[:, keep]
    out_sub = model.cm_eval_ls(Xs, y, w, beta[keep],
                               np.ones(len(keep), np.float32), lam, k=8)
    np.testing.assert_allclose(np.array(out_full[0])[keep],
                               np.array(out_sub[0]), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(float(out_full[3]), float(out_sub[3]),
                               atol=1e-3, rtol=1e-3)
