"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this
package is checked against the matching function here by
``python/tests/test_kernel.py`` (hypothesis sweeps over shapes, seeds
and parameter ranges) before the AOT artifacts are trusted.

Numeric conventions (shared with the rust native engine,
``rust/src/model`` / ``rust/src/cm``):

  least squares   f(u, y) = 1/2 (u - y)^2
    P(beta)  = 1/2 sum_j w_j r_j^2 + lam * ||beta||_1,  r = y - X beta
    theta^   = r / lam              (padded rows have X = y = 0 => r = 0)
    D(theta) = 1/2 ||y||_w^2 - lam^2/2 ||theta - y/lam||_w^2

  logistic        f(u, y) = log(1 + exp(-y u)),  y in {-1, +1}
    theta^_j = w_j y_j sigmoid(-y_j u_j) / lam
    D(theta) = -sum_j w_j [s log s + (1-s) log(1-s)],  s = lam theta_j y_j

Coordinate minimization (shooting) updates coordinate i cyclically:

  LS:       z = beta_i + x_i.r / n2_i,          beta_i <- S(z, lam/n2_i)
  logistic: g = x_i.f'(u), H = 1/4 * n2_i,
            z = beta_i - g/H,                   beta_i <- S(z, lam/H)

with S the soft-threshold and n2_i = sum_j w_j x_ji^2. Masked-out
(inactive / padding) columns are never touched and keep beta_i = 0.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def soft_threshold(z, t):
    """Soft-thresholding operator S(z, t) = sign(z) * max(|z| - t, 0)."""
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - t, 0.0)


# ---------------------------------------------------------------------------
# numpy references (plain loops — slow, unambiguous)
# ---------------------------------------------------------------------------


def cm_epochs_ls_np(X, y, w, beta, mask, lam, k):
    """K cyclic CM epochs for weighted LASSO least squares (numpy loops)."""
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    w = np.asarray(w, np.float64)
    mask = np.asarray(mask, np.float64)
    beta = np.asarray(beta, np.float64) * mask  # masked columns forced to 0
    n2 = (w[:, None] * X * X).sum(axis=0)
    r = y - X @ beta
    p = X.shape[1]
    for _ in range(k):
        for i in range(p):
            if mask[i] == 0.0 or n2[i] <= 0.0:
                continue
            xi = X[:, i]
            g = float((w * xi * r).sum())
            z = beta[i] + g / n2[i]
            bn = np.sign(z) * max(abs(z) - lam / n2[i], 0.0)
            r += xi * (beta[i] - bn)
            beta[i] = bn
    return beta.astype(np.float32), r.astype(np.float32)


def cm_epochs_logistic_np(X, y, w, beta, mask, lam, k):
    """K cyclic CM epochs for L1 logistic regression (numpy loops)."""
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    w = np.asarray(w, np.float64)
    mask = np.asarray(mask, np.float64)
    beta = np.asarray(beta, np.float64) * mask  # masked columns forced to 0
    n2 = (w[:, None] * X * X).sum(axis=0)
    u = X @ beta
    p = X.shape[1]
    for _ in range(k):
        for i in range(p):
            if mask[i] == 0.0 or n2[i] <= 0.0:
                continue
            xi = X[:, i]
            # f'(u) = -y * sigmoid(-y u)
            fp = -y / (1.0 + np.exp(y * u))
            g = float((w * xi * fp).sum())
            h = 0.25 * n2[i]
            z = beta[i] - g / h
            bn = np.sign(z) * max(abs(z) - lam / h, 0.0)
            u += xi * (bn - beta[i])
            beta[i] = bn
    return beta.astype(np.float32), u.astype(np.float32)


def scores_np(X, theta):
    """|X^T theta| and squared column norms (numpy)."""
    X = np.asarray(X, np.float64)
    theta = np.asarray(theta, np.float64)
    s = np.abs(X.T @ theta)
    n2 = (X * X).sum(axis=0)
    return s.astype(np.float32), n2.astype(np.float32)


# ---------------------------------------------------------------------------
# jnp references (also used as the L2 eval maths in model.py)
# ---------------------------------------------------------------------------


def eval_ls_ref(X, y, w, beta, mask, lam, resid):
    """Primal, projected dual, dual value, gap and active scores for LS.

    ``resid`` must equal y - X beta (as produced by the CM kernel).
    Returns (primal, dual, gap, theta, scores) matching model.cm_eval_ls.
    """
    beta = beta * mask
    primal = 0.5 * jnp.sum(w * resid * resid) + lam * jnp.sum(jnp.abs(beta))
    theta_hat = w * resid / lam
    # max over *masked* columns only
    corr = jnp.abs(X.T @ theta_hat) * mask
    mx = jnp.maximum(jnp.max(corr), 1e-12)
    # optimal feasible scaling (clipped): tau* = y.theta^ / (lam ||theta^||^2)
    denom = jnp.maximum(lam * jnp.sum(theta_hat * theta_hat), 1e-30)
    tau_star = jnp.sum(w * y * theta_hat) / denom
    tau = jnp.clip(tau_star, -1.0 / mx, 1.0 / mx)
    theta = tau * theta_hat
    diff = theta - w * y / lam
    dual = 0.5 * jnp.sum(w * y * y) - 0.5 * lam * lam * jnp.sum(diff * diff)
    gap = jnp.maximum(primal - dual, 0.0)
    scores = jnp.abs(X.T @ theta)
    return primal, dual, gap, theta, scores


def _xlogx(s):
    return jnp.where(s > 0.0, s * jnp.log(jnp.maximum(s, 1e-30)), 0.0)


def eval_logistic_ref(X, y, w, beta, mask, lam, u):
    """Primal, projected dual, dual value, gap and scores for logistic.

    ``u`` must equal X beta (as produced by the logistic CM kernel).
    """
    beta = beta * mask
    loss = jnp.sum(w * jnp.logaddexp(0.0, -y * u))
    primal = loss + lam * jnp.sum(jnp.abs(beta))
    sig = 1.0 / (1.0 + jnp.exp(y * u))  # sigmoid(-y u)
    theta_hat = w * y * sig / lam
    corr = jnp.abs(X.T @ theta_hat) * mask
    mx = jnp.maximum(jnp.max(corr), 1e-12)
    tau = jnp.minimum(1.0, 1.0 / mx)
    theta = tau * theta_hat
    s = jnp.clip(lam * theta * y, 0.0, 1.0)
    dual = -jnp.sum(w * (_xlogx(s) + _xlogx(1.0 - s)))
    gap = jnp.maximum(primal - dual, 0.0)
    scores = jnp.abs(X.T @ theta)
    return primal, dual, gap, theta, scores
