"""L1 Pallas kernel: tiled screening-score scan  s = |X^T theta|, n2 = ||x_i||^2.

This is the ADD-operation hot spot: every SAIF outer iteration scans
the *full* remaining set for the most-violating features
(max_i |x_i^T theta_t|), an O(n p) matvec that dominates once the
active sub-problem is small. It is also how lambda_max and the initial
correlations |X^T f'(0)| are computed.

TPU adaptation (DESIGN.md §3): the grid walks column blocks of X; each
grid step stages an (n_cap, BLOCK_P) tile HBM->VMEM via BlockSpec and
issues one MXU matvec against the VMEM-resident theta, writing a
BLOCK_P-slice of |scores| and column norms. This is the natural
translation of the paper's "scan all p columns" loop into an
HBM-bandwidth-bound streaming kernel.

interpret=True so the lowered HLO runs on the CPU PJRT client.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 256


def _scores_kernel(x_ref, theta_ref, s_ref, n2_ref):
    x = x_ref[...]
    th = theta_ref[...]
    s_ref[...] = jnp.abs(x.T @ th)
    n2_ref[...] = jnp.sum(x * x, axis=0)


@jax.jit
def scores(x, theta):
    """|X^T theta| and squared column norms, tiled over column blocks."""
    n, p = x.shape
    bp = BLOCK_P if p % BLOCK_P == 0 else p
    grid = (p // bp,)
    return pl.pallas_call(
        _scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ),
        interpret=True,
    )(x, theta)
