"""L1 Pallas kernel: K cyclic CM epochs for L1-regularized logistic loss.

Same structure as cm_epoch.py but the carried state is the margin
vector u = X beta (instead of the residual), and each coordinate takes
a Lipschitz-majorized Newton step:

    fp     = -y * sigmoid(-y u)            (pointwise loss derivative)
    g      = <x_i, w * fp>
    H      = 1/4 * n2_i                    (1/4 = logistic curvature bound)
    z      = beta_i - g / H
    beta_i <- S(z, lam / H)
    u      += x_i * (beta_i - old)

This is the standard majorize-then-soft-threshold coordinate update
(the role L1General plays in the paper's logistic experiments).
Labels are +/-1; padded samples carry w = 0 (and y = 0), so their
contribution to g and to the primal value vanishes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cm_logistic_kernel(x_ref, y_ref, w_ref, beta_in_ref, mask_ref, lam_ref,
                        beta_ref, u_ref, *, k: int, p_cap: int):
    lam = lam_ref[0, 0]
    x = x_ref[...]
    y = y_ref[...]
    w = w_ref[...]
    beta0 = beta_in_ref[...] * mask_ref[...]
    n2 = jnp.sum(w[:, None] * x * x, axis=0)
    beta_ref[...] = beta0
    u_ref[...] = x @ beta0

    def body(step, _):
        i = step % p_cap
        xi = jax.lax.dynamic_slice(x, (0, i), (x.shape[0], 1))[:, 0]
        n2i = jax.lax.dynamic_slice(n2, (i,), (1,))[0]
        mi = jax.lax.dynamic_slice(mask_ref[...], (i,), (1,))[0]
        bi = beta_ref[pl.ds(i, 1)][0]
        u = u_ref[...]
        fp = -y / (1.0 + jnp.exp(y * u))
        g = jnp.sum(w * xi * fp)
        live = (mi > 0.0) & (n2i > 0.0)
        h = 0.25 * n2i
        inv = jnp.where(live, 1.0 / jnp.maximum(h, 1e-30), 0.0)
        z = bi - g * inv
        bn = jnp.sign(z) * jnp.maximum(jnp.abs(z) - lam * inv, 0.0)
        bn = jnp.where(live, bn, bi)
        u_ref[...] = u + xi * (bn - bi)
        beta_ref[pl.ds(i, 1)] = bn[None]
        return 0

    jax.lax.fori_loop(0, k * p_cap, body, 0)


@functools.partial(jax.jit, static_argnames=("k",))
def cm_epochs_logistic(x, y, w, beta, mask, lam, k: int = 10):
    """K cyclic CM epochs for L1 logistic. Returns (beta', u = X beta')."""
    n, p = x.shape
    lam2d = jnp.asarray(lam, jnp.float32).reshape(1, 1)
    kern = functools.partial(_cm_logistic_kernel, k=k, p_cap=p)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(x, y, w, beta, mask, lam2d)
