"""L1 Pallas kernel: K cyclic coordinate-minimization epochs (least squares).

This is the SAIF inner-loop hot spot (the "shooting algorithm" of
Fu 1998, the base algorithm the paper uses): for each active
coordinate i,

    g      = <x_i, r>                      (weighted residual corr.)
    z      = beta_i + g / n2_i
    beta_i <- S(z, lam / n2_i)             (soft-threshold)
    r      += x_i * (old beta_i - beta_i)  (rank-1 residual repair)

run cyclically over all coordinates, K epochs per kernel call. The
coordinate loop is inherently sequential — the kernel expresses it as
an in-kernel ``fori_loop`` over K * p_cap steps with the residual held
in the output ref (VMEM-resident on a real TPU; SAIF's whole point is
that the active block is small enough to stay resident: p_cap <= 1024,
n_cap <= 2048 => X block <= 8 MB f32, within VMEM reach with column
sub-tiling).

TPU adaptation (DESIGN.md §3): the paper's CPU implementation walks
columns from main memory; here BlockSpec pins the entire active
sub-matrix + residual into VMEM once per call and the MXU/VPU do the
length-n dot/axpy pairs. interpret=True is REQUIRED for CPU PJRT
execution — the kernel then lowers to plain HLO (a while loop over
fused dot/axpy), which is exactly what the rust runtime loads.

Masked-out columns (mask == 0) and zero-norm columns are skipped:
their beta entries are forced to 0 and the residual is untouched.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cm_ls_kernel(x_ref, y_ref, w_ref, beta_in_ref, mask_ref, lam_ref,
                  beta_ref, r_ref, *, k: int, p_cap: int):
    """Kernel body. Refs: X (n,p), y (n,), w (n,), beta_in (p,), mask (p,),
    lam (1,1) scalar; outputs beta (p,), r (n,) residual."""
    lam = lam_ref[0, 0]
    x = x_ref[...]
    w = w_ref[...]
    beta0 = beta_in_ref[...] * mask_ref[...]
    # weighted squared column norms (recomputed in-kernel: cheap vs K epochs)
    n2 = jnp.sum(w[:, None] * x * x, axis=0)
    beta_ref[...] = beta0
    r_ref[...] = y_ref[...] - x @ beta0

    def body(step, _):
        i = step % p_cap
        xi = jax.lax.dynamic_slice(x, (0, i), (x.shape[0], 1))[:, 0]
        n2i = jax.lax.dynamic_slice(n2, (i,), (1,))[0]
        mi = jax.lax.dynamic_slice(mask_ref[...], (i,), (1,))[0]
        bi = beta_ref[pl.ds(i, 1)][0]
        r = r_ref[...]
        g = jnp.sum(w * xi * r)
        live = (mi > 0.0) & (n2i > 0.0)
        inv = jnp.where(live, 1.0 / jnp.maximum(n2i, 1e-30), 0.0)
        z = bi + g * inv
        bn = jnp.sign(z) * jnp.maximum(jnp.abs(z) - lam * inv, 0.0)
        bn = jnp.where(live, bn, bi)
        r_ref[...] = r + xi * (bi - bn)
        beta_ref[pl.ds(i, 1)] = bn[None]
        return 0

    jax.lax.fori_loop(0, k * p_cap, body, 0)


@functools.partial(jax.jit, static_argnames=("k",))
def cm_epochs_ls(x, y, w, beta, mask, lam, k: int = 10):
    """K cyclic CM epochs for LS LASSO. Returns (beta', residual)."""
    n, p = x.shape
    lam2d = jnp.asarray(lam, jnp.float32).reshape(1, 1)
    kern = functools.partial(_cm_ls_kernel, k=k, p_cap=p)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(x, y, w, beta, mask, lam2d)
