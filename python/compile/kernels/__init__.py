"""L1 Pallas kernels (build-time only; lowered into the AOT artifacts)."""

from .cm_epoch import cm_epochs_ls
from .logistic_cm import cm_epochs_logistic
from .scores import scores
from . import ref

__all__ = ["cm_epochs_ls", "cm_epochs_logistic", "scores", "ref"]
