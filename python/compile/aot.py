"""AOT pipeline: lower the L2 graphs to HLO text + manifest for rust.

Interchange is HLO *text*, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts [--report]

Artifacts are shape buckets (DESIGN.md §2). For every bucket this
writes `<name>.hlo.txt` plus one `manifest.json` describing inputs /
outputs so the rust runtime can pack literals without guessing.

--report prints the per-bucket VMEM footprint / MXU utilization
estimate used in DESIGN.md §Perf (the real-TPU story; interpret-mode
CPU timings are NOT a TPU proxy).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

K_EPOCHS = 10

# (n_cap, p_cap) buckets. CM buckets hold the padded *active* block;
# scores buckets hold the full feature matrix for the ADD scan.
CM_LS_BUCKETS = [(128, 64), (128, 256), (128, 1024),
                 (512, 64), (512, 256), (512, 1024)]
CM_LOG_BUCKETS = [(512, 64), (512, 256), (512, 1024),
                  (2048, 64), (2048, 256)]
SCORES_BUCKETS = [(128, 128), (128, 5120), (512, 128), (512, 5120),
                  (512, 8192), (2048, 256)]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_cm(kind: str, n: int, p: int):
    fn = model.cm_eval_ls if kind == "cm_ls" else model.cm_eval_logistic
    return jax.jit(fn, static_argnames=("k",)).lower(
        _spec(n, p), _spec(n), _spec(n), _spec(p), _spec(p), _spec(), k=K_EPOCHS
    )


def lower_scores(n: int, p: int):
    return jax.jit(model.scores_scan).lower(_spec(n, p), _spec(n))


def vmem_report(kind: str, n: int, p: int) -> str:
    """VMEM footprint + MXU utilization estimate for the TPU mapping."""
    f = 4  # f32 bytes
    if kind == "scores":
        blk = min(256, p)
        vmem = (n * blk + n + 2 * blk) * f
        # streaming matvec: 2*n*p flops over n*p*f bytes from HBM
        ai = 2.0 / f  # flops/byte — HBM-bandwidth bound
        note = f"block ({n},{blk}), arith intensity {ai:.2f} fl/B (BW-bound)"
    else:
        vmem = (n * p + 3 * n + 3 * p) * f  # X + y,w,resid + beta,mask,n2
        # CM epoch: 4*n flops per coordinate step; sequential, VPU-bound
        note = "whole active block resident; dot+axpy per coord (VPU)"
    return f"{kind} n={n} p={p}: VMEM ~{vmem/2**20:.2f} MiB ({note})"


def build(out_dir: str, report: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"k_epochs": K_EPOCHS, "artifacts": []}
    jobs = (
        [("cm_ls", n, p) for (n, p) in CM_LS_BUCKETS]
        + [("cm_log", n, p) for (n, p) in CM_LOG_BUCKETS]
        + [("scores", n, p) for (n, p) in SCORES_BUCKETS]
    )
    for kind, n, p in jobs:
        name = f"{kind}_n{n}_p{p}"
        path = os.path.join(out_dir, name + ".hlo.txt")
        lowered = lower_scores(n, p) if kind == "scores" else lower_cm(kind, n, p)
        text = to_hlo_text(lowered)
        assert len(text) > 100, f"suspiciously small HLO for {name}"
        with open(path, "w") as f:
            f.write(text)
        if kind == "scores":
            inputs = [["x", [n, p]], ["theta", [n]]]
            outputs = [["scores", [p]], ["n2", [p]]]
        else:
            inputs = [["x", [n, p]], ["y", [n]], ["w", [n]],
                      ["beta", [p]], ["mask", [p]], ["lam", []]]
            outputs = [["beta", [p]], ["primal", []], ["dual", []],
                       ["gap", []], ["theta", [n]], ["scores", [p]]]
        manifest["artifacts"].append({
            "name": name, "kind": kind, "n": n, "p": p,
            "k": 0 if kind == "scores" else K_EPOCHS,
            "file": name + ".hlo.txt",
            "inputs": inputs, "outputs": outputs,
        })
        if report:
            print(vmem_report(kind, n, p))
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--report", action="store_true",
                    help="print VMEM/MXU estimates (DESIGN.md §Perf)")
    args = ap.parse_args()
    build(args.out, report=args.report)


if __name__ == "__main__":
    main()
