"""L2: jax compute graphs composing the L1 Pallas kernels.

Each function here is one AOT artifact (lowered by aot.py to HLO text,
loaded by ``rust/src/runtime``). They are the complete numeric payload
of one SAIF outer-loop step:

  cm_eval_ls / cm_eval_logistic
      K CM epochs on the padded active block (L1 kernel), then the
      duality-gap evaluation: primal value, feasible projected dual
      theta, dual value, gap, and per-active-column screening scores
      |x_i^T theta| (for DEL).  Outputs, in tuple order:
        0: beta'   (p_cap,)   updated coefficients (masked)
        1: primal  ()         P_t(beta')
        2: dual    ()         D(theta)
        3: gap     ()         max(P - D, 0)
        4: theta   (n_cap,)   feasible dual point
        5: scores  (p_cap,)   |x_i^T theta| over the active block

  scores_scan
      |X^T theta| + squared column norms over the FULL feature matrix
      (for ADD / lambda_max / initial correlations).  Outputs:
        0: scores (p_cap,)    1: n2 (p_cap,)

All shapes are static per artifact (shape buckets, DESIGN.md §2);
the rust runtime pads with zero rows / masked columns.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import cm_epochs_ls, cm_epochs_logistic, scores
from .kernels.ref import eval_ls_ref, eval_logistic_ref


@functools.partial(jax.jit, static_argnames=("k",))
def cm_eval_ls(x, y, w, beta, mask, lam, k: int = 10):
    """K LS CM epochs + duality-gap evaluation (one SAIF inner step)."""
    beta1, resid = cm_epochs_ls(x, y, w, beta, mask, lam, k=k)
    beta1 = beta1 * mask
    primal, dual, gap, theta, sc = eval_ls_ref(x, y, w, beta1, mask, lam, resid)
    return beta1, primal, dual, gap, theta, sc


@functools.partial(jax.jit, static_argnames=("k",))
def cm_eval_logistic(x, y, w, beta, mask, lam, k: int = 10):
    """K logistic CM epochs + duality-gap evaluation."""
    beta1, u = cm_epochs_logistic(x, y, w, beta, mask, lam, k=k)
    beta1 = beta1 * mask
    primal, dual, gap, theta, sc = eval_logistic_ref(x, y, w, beta1, mask, lam, u)
    return beta1, primal, dual, gap, theta, sc


@jax.jit
def scores_scan(x, theta):
    """Full-matrix screening scan (ADD hot spot): |X^T theta|, ||x_i||^2."""
    return scores(x, theta)
